//! Deterministic link fault injection.
//!
//! [`ImpairedTransport`] decorates any [`Transport`]'s *send* side with
//! seeded drop / duplicate / reorder / corrupt faults, so the delivered
//! frame sequence is a pure function of `(seed, sent sequence)` — the
//! same-seed determinism contract the rest of the framework lives by.
//! Faults are applied where frames *enter* the wire:
//!
//! * **drop** — the frame never reaches the inner transport;
//! * **duplicate** — the frame is transmitted twice back-to-back;
//! * **reorder** — the frame is parked in a bounded delay queue and
//!   released after 1–4 later sends have overtaken it (a "send" is the
//!   unit of time here, not wall clock, so the schedule replays
//!   bit-identically);
//! * **corrupt** — the frame is truncated at a random offset or has its
//!   leading magic byte smashed. Both mutilations are guaranteed to
//!   fail `Msg::decode_on`, so corruption can never silently deliver a
//!   wrong-but-decodable frame; the loss-tolerant receive path counts
//!   and drops it and the reliable layer retransmits.
//!
//! Each unidirectional channel gets its own PRNG stream
//! ([`stream_seed`]) so per-direction schedules are independent, and
//! [`ImpairCfg::dir`] restricts faults to one direction (`up` =
//! VM→HDL, `down` = HDL→VM) — the blackhole scenarios in the e2e
//! recovery tests are `dir=down,drop=1.0`.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use super::msg::Side;
use super::transport::{Doorbell, Transport};
use crate::testutil::XorShift64;
use crate::{Error, Result};

/// Ceiling of the reorder delay queue; when full the oldest parked
/// frame is forced out before a new one is parked.
const REORDER_CAP: usize = 32;
/// A parked frame is released after 1..=REORDER_SPAN further sends.
const REORDER_SPAN: u64 = 4;

/// Which direction(s) of the link the faults apply to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ImpairDir {
    /// Both directions (the default).
    #[default]
    Both,
    /// VM → HDL only (MMIO requests, DMA read responses).
    Up,
    /// HDL → VM only (MMIO responses, DMA requests, interrupts).
    Down,
}

/// Parsed `--impair` spec. Probabilities are stored in parts-per-
/// million so the config stays `Eq` and float drift can never leak
/// into the deterministic fault schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImpairCfg {
    pub drop_ppm: u32,
    pub dup_ppm: u32,
    pub reorder_ppm: u32,
    pub corrupt_ppm: u32,
    /// Per-send wall-clock jitter ceiling in microseconds: each
    /// payload send sleeps a seeded pseudo-random duration in
    /// `[0, jitter_us]` µs. Wall-only (like `--device-link-latency`),
    /// so device-cycle determinism is untouched; the sleep *sequence*
    /// is a pure function of the seed.
    pub jitter_us: u32,
    pub seed: u64,
    pub dir: ImpairDir,
}

impl Default for ImpairCfg {
    fn default() -> Self {
        Self {
            drop_ppm: 0,
            dup_ppm: 0,
            reorder_ppm: 0,
            corrupt_ppm: 0,
            jitter_us: 0,
            seed: 1,
            dir: ImpairDir::Both,
        }
    }
}

impl ImpairCfg {
    /// Parse a `drop=0.05,dup=0.01,reorder=0.1,corrupt=0.02,seed=7,
    /// dir=up|down|both` spec (any subset of keys, any order).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut cfg = ImpairCfg::default();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (k, v) = tok.split_once('=').ok_or_else(|| {
                Error::config(format!("impair spec item {tok:?} is not key=value"))
            })?;
            match k {
                "drop" => cfg.drop_ppm = parse_prob(k, v)?,
                "dup" => cfg.dup_ppm = parse_prob(k, v)?,
                "reorder" => cfg.reorder_ppm = parse_prob(k, v)?,
                "corrupt" => cfg.corrupt_ppm = parse_prob(k, v)?,
                "jitter" => {
                    cfg.jitter_us = v.parse().map_err(|_| {
                        Error::config(format!(
                            "impair jitter={v:?} is not a whole number of µs"
                        ))
                    })?
                }
                "seed" => cfg.seed = parse_seed(v)?,
                "dir" => {
                    cfg.dir = match v {
                        "both" => ImpairDir::Both,
                        "up" => ImpairDir::Up,
                        "down" => ImpairDir::Down,
                        other => {
                            return Err(Error::config(format!(
                                "impair dir {other:?} (want up, down, or both)"
                            )))
                        }
                    }
                }
                other => {
                    return Err(Error::config(format!(
                        "unknown impair key {other:?} \
                         (drop/dup/reorder/corrupt/jitter/seed/dir)"
                    )))
                }
            }
        }
        Ok(cfg)
    }

    /// True when the spec does nothing at all (no loss fault, no
    /// jitter).
    pub fn is_null(&self) -> bool {
        !self.has_loss_faults() && self.jitter_us == 0
    }

    /// True when any frame-mutilating fault has a nonzero probability
    /// — the condition for wrapping the send path in an
    /// [`ImpairedTransport`] (jitter alone never touches frames).
    pub fn has_loss_faults(&self) -> bool {
        self.drop_ppm != 0
            || self.dup_ppm != 0
            || self.reorder_ppm != 0
            || self.corrupt_ppm != 0
    }

    /// Whether a channel whose *sender* is `sender` is covered by
    /// [`ImpairCfg::dir`].
    pub fn applies_to(&self, sender: Side) -> bool {
        match (self.dir, sender) {
            (ImpairDir::Both, _) => true,
            (ImpairDir::Up, Side::Vm) => true,
            (ImpairDir::Down, Side::Hdl) => true,
            _ => false,
        }
    }
}

fn parse_prob(key: &str, v: &str) -> Result<u32> {
    let p: f64 = v
        .parse()
        .map_err(|_| Error::config(format!("impair {key}={v:?} is not a number")))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(Error::config(format!(
            "impair {key}={v} out of range (probability in [0, 1])"
        )));
    }
    Ok((p * 1_000_000.0).round() as u32)
}

fn parse_seed(v: &str) -> Result<u64> {
    let r = if let Some(hex) = v.strip_prefix("0x") {
        u64::from_str_radix(hex, 16)
    } else {
        v.parse()
    };
    r.map_err(|_| Error::config(format!("bad impair seed {v:?}")))
}

/// Derive the per-channel PRNG seed from the config seed and the
/// channel coordinates (device, sending side, pair index) — splitmix64
/// finalizer so adjacent coordinates land in unrelated streams.
pub fn stream_seed(seed: u64, device: u8, sender: Side, pair: u8) -> u64 {
    let tag = ((device as u64) << 16)
        | ((matches!(sender, Side::Hdl) as u64) << 8)
        | pair as u64;
    let mut z = seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-channel fault counters (the recovery story's ground truth in
/// tests: every delivered-minus-sent discrepancy must be explained by
/// these).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ImpairStats {
    /// Frames passed through unmolested (including the original of a
    /// duplicated frame).
    pub forwarded: u64,
    pub dropped: u64,
    pub duplicated: u64,
    pub reordered: u64,
    pub corrupted: u64,
}

/// Send-side fault-injection decorator over any [`Transport`].
///
/// All faults happen on `send`; the receive direction delegates
/// straight through. Inner send errors are swallowed (a lossy wire has
/// no delivery receipt) — the reliable layer's retransmit is the only
/// recovery mechanism, which is exactly what the harness exercises.
pub struct ImpairedTransport {
    inner: Box<dyn Transport>,
    rng: XorShift64,
    cfg: ImpairCfg,
    /// Monotone send counter — the fault schedule's clock (frames
    /// parked for reorder are released when this passes their mark, so
    /// the schedule replays identically run to run).
    sends: u64,
    /// Parked `(release_at, frame)` entries, in park order.
    held: VecDeque<(u64, Vec<u8>)>,
    pub stats: ImpairStats,
}

impl ImpairedTransport {
    /// Wrap `inner`; `seed` should come from [`stream_seed`] so every
    /// unidirectional channel has an independent schedule.
    pub fn new(inner: Box<dyn Transport>, cfg: ImpairCfg, seed: u64) -> Self {
        Self {
            inner,
            rng: XorShift64::new(seed),
            cfg,
            sends: 0,
            held: VecDeque::new(),
            stats: ImpairStats::default(),
        }
    }

    fn roll(&mut self, ppm: u32) -> bool {
        ppm > 0 && self.rng.below(1_000_000) < ppm as u64
    }

    /// Mutilate a frame such that decode is guaranteed to fail:
    /// truncation below full length, or a smashed leading magic byte.
    /// Never random bit flips — those could yield a decodable frame
    /// with wrong contents, which without a payload CRC would corrupt
    /// the co-sim silently instead of exercising recovery.
    fn mangle(&mut self, frame: &[u8]) -> Vec<u8> {
        if frame.len() >= 2 && self.rng.chance(1, 2) {
            let cut = self.rng.below(frame.len() as u64) as usize;
            frame.get(..cut).unwrap_or_default().to_vec()
        } else {
            let mut v = frame.to_vec();
            if let Some(b) = v.first_mut() {
                *b ^= 0xFF;
            }
            v
        }
    }

    /// Release parked frames whose mark has passed (in park order).
    fn release_due(&mut self) {
        let mut i = 0;
        while i < self.held.len() {
            let due = self
                .held
                .get(i)
                .is_some_and(|(at, _)| *at <= self.sends);
            if due {
                if let Some((_, f)) = self.held.remove(i) {
                    let _ = self.inner.send(&f);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Frames currently parked in the reorder queue.
    pub fn held_len(&self) -> usize {
        self.held.len()
    }
}

impl Transport for ImpairedTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.sends += 1;
        if self.roll(self.cfg.drop_ppm) {
            self.stats.dropped += 1;
        } else if self.roll(self.cfg.corrupt_ppm) {
            self.stats.corrupted += 1;
            let mangled = self.mangle(frame);
            let _ = self.inner.send(&mangled);
        } else if self.roll(self.cfg.reorder_ppm) {
            self.stats.reordered += 1;
            if self.held.len() >= REORDER_CAP {
                if let Some((_, f)) = self.held.pop_front() {
                    let _ = self.inner.send(&f);
                }
            }
            let span = 1 + self.rng.below(REORDER_SPAN);
            self.held.push_back((self.sends + span, frame.to_vec()));
        } else {
            let dup = self.roll(self.cfg.dup_ppm);
            self.stats.forwarded += 1;
            let _ = self.inner.send(frame);
            if dup {
                self.stats.duplicated += 1;
                let _ = self.inner.send(frame);
            }
        }
        self.release_due();
        Ok(())
    }

    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        self.inner.try_recv()
    }

    fn try_recv_into(&mut self, out: &mut Vec<u8>) -> Result<bool> {
        self.inner.try_recv_into(out)
    }

    fn ready(&mut self) -> Result<bool> {
        self.inner.ready()
    }

    fn set_doorbell(&mut self, db: Arc<Doorbell>) {
        self.inner.set_doorbell(db);
    }

    fn peek_reconnected(&self) -> bool {
        self.inner.peek_reconnected()
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        self.inner.recv_timeout(timeout)
    }

    fn connected(&self) -> bool {
        self.inner.connected()
    }

    fn reconnect(&mut self) -> Result<bool> {
        self.inner.reconnect()
    }

    fn take_reconnected(&mut self) -> bool {
        self.inner.take_reconnected()
    }

    fn lossy(&self) -> bool {
        true
    }

    fn label(&self) -> &'static str {
        "impaired"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::link::transport::make_inproc_pair;

    fn wrapped(cfg: ImpairCfg, seed: u64) -> (ImpairedTransport, crate::link::InProcTransport) {
        let (tx, rx) = make_inproc_pair();
        (ImpairedTransport::new(Box::new(tx), cfg, seed), rx)
    }

    fn drain(rx: &mut crate::link::InProcTransport) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(f) = rx.try_recv().unwrap() {
            out.push(f);
        }
        out
    }

    #[test]
    fn parse_full_spec() {
        let c = ImpairCfg::parse("drop=0.05,dup=0.01,reorder=0.1,corrupt=0.02,seed=7,dir=up")
            .unwrap();
        assert_eq!(c.drop_ppm, 50_000);
        assert_eq!(c.dup_ppm, 10_000);
        assert_eq!(c.reorder_ppm, 100_000);
        assert_eq!(c.corrupt_ppm, 20_000);
        assert_eq!(c.seed, 7);
        assert_eq!(c.dir, ImpairDir::Up);
        assert!(!c.is_null());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(ImpairCfg::parse("drop").is_err());
        assert!(ImpairCfg::parse("drop=2.0").is_err());
        assert!(ImpairCfg::parse("drop=-0.1").is_err());
        assert!(ImpairCfg::parse("drop=abc").is_err());
        assert!(ImpairCfg::parse("warp=0.5").is_err());
        assert!(ImpairCfg::parse("dir=sideways").is_err());
        assert!(ImpairCfg::parse("seed=zzz").is_err());
    }

    #[test]
    fn parse_jitter_key() {
        let c = ImpairCfg::parse("jitter=250,seed=9").unwrap();
        assert_eq!(c.jitter_us, 250);
        assert!(!c.is_null(), "jitter-only spec must not be null");
        assert!(!c.has_loss_faults(), "jitter is not a loss fault");
        assert!(ImpairCfg::parse("jitter=1.5").is_err());
        assert!(ImpairCfg::parse("jitter=-3").is_err());
    }

    #[test]
    fn parse_hex_seed_and_empty_spec() {
        assert_eq!(ImpairCfg::parse("seed=0x10").unwrap().seed, 16);
        let c = ImpairCfg::parse("").unwrap();
        assert!(c.is_null());
        assert_eq!(c, ImpairCfg::default());
    }

    #[test]
    fn dir_selects_sender_side() {
        let up = ImpairCfg { dir: ImpairDir::Up, ..Default::default() };
        assert!(up.applies_to(Side::Vm));
        assert!(!up.applies_to(Side::Hdl));
        let down = ImpairCfg { dir: ImpairDir::Down, ..Default::default() };
        assert!(!down.applies_to(Side::Vm));
        assert!(down.applies_to(Side::Hdl));
        let both = ImpairCfg::default();
        assert!(both.applies_to(Side::Vm) && both.applies_to(Side::Hdl));
    }

    #[test]
    fn stream_seeds_diverge_per_channel() {
        let s = 42;
        let a = stream_seed(s, 0, Side::Vm, 0);
        let b = stream_seed(s, 0, Side::Vm, 1);
        let c = stream_seed(s, 0, Side::Hdl, 0);
        let d = stream_seed(s, 1, Side::Vm, 0);
        assert!(a != b && a != c && a != d && b != c && b != d && c != d);
        assert_eq!(a, stream_seed(s, 0, Side::Vm, 0), "must be a pure function");
    }

    #[test]
    fn drop_one_drops_everything() {
        let cfg = ImpairCfg { drop_ppm: 1_000_000, ..Default::default() };
        let (mut t, mut rx) = wrapped(cfg, 1);
        for _ in 0..50 {
            t.send(b"frame").unwrap();
        }
        assert_eq!(t.stats.dropped, 50);
        assert!(drain(&mut rx).is_empty());
    }

    #[test]
    fn null_cfg_is_transparent() {
        let (mut t, mut rx) = wrapped(ImpairCfg::default(), 1);
        for i in 0..20u8 {
            t.send(&[i]).unwrap();
        }
        let got = drain(&mut rx);
        assert_eq!(got.len(), 20);
        for (i, f) in got.iter().enumerate() {
            assert_eq!(f, &vec![i as u8]);
        }
        assert_eq!(t.stats.forwarded, 20);
        assert!(t.lossy());
    }

    #[test]
    fn dup_duplicates_back_to_back() {
        let cfg = ImpairCfg { dup_ppm: 1_000_000, ..Default::default() };
        let (mut t, mut rx) = wrapped(cfg, 3);
        t.send(b"x").unwrap();
        t.send(b"y").unwrap();
        let got = drain(&mut rx);
        assert_eq!(got, vec![b"x".to_vec(), b"x".to_vec(), b"y".to_vec(), b"y".to_vec()]);
        assert_eq!(t.stats.duplicated, 2);
    }

    #[test]
    fn reorder_holds_then_releases_all() {
        let cfg = ImpairCfg { reorder_ppm: 500_000, ..Default::default() };
        let (mut t, mut rx) = wrapped(cfg, 9);
        let n = 200u16;
        for i in 0..n {
            t.send(&i.to_le_bytes()).unwrap();
        }
        // Flush the tail of the delay queue with padding sends (a real
        // sender's retransmits play this role); a parked frame can be
        // re-parked, so pad until the queue is provably empty.
        let mut pads = 0;
        while t.held_len() > 0 {
            t.send(b"pad").unwrap();
            pads += 1;
            assert!(pads < 10_000, "delay queue never drained");
        }
        let got = drain(&mut rx);
        let payload: Vec<_> = got.iter().filter(|f| f.as_slice() != b"pad").collect();
        assert_eq!(payload.len(), n as usize, "reorder must never lose frames");
        assert!(t.stats.reordered > 0);
        // And it genuinely reordered something.
        let in_order = payload.windows(2).all(|w| w[0] <= w[1]);
        assert!(!in_order, "0.5 reorder over 200 frames left order intact");
    }

    #[test]
    fn corrupt_never_yields_a_decodable_frame() {
        use crate::link::Msg;
        let cfg = ImpairCfg { corrupt_ppm: 1_000_000, ..Default::default() };
        let (mut t, mut rx) = wrapped(cfg, 5);
        for i in 0..100u64 {
            let f = Msg::MmioRead { tag: i, bar: 0, addr: i, len: 4 }.encode(i + 1);
            t.send(&f).unwrap();
        }
        assert_eq!(t.stats.corrupted, 100);
        for f in drain(&mut rx) {
            assert!(Msg::decode_on(&f).is_err(), "corrupt frame decoded: {f:?}");
        }
    }

    #[test]
    fn same_seed_same_fault_schedule() {
        let cfg = ImpairCfg {
            drop_ppm: 200_000,
            dup_ppm: 100_000,
            reorder_ppm: 150_000,
            corrupt_ppm: 50_000,
            ..Default::default()
        };
        let run = |seed: u64| {
            let (mut t, mut rx) = wrapped(cfg, seed);
            for i in 0..500u32 {
                t.send(&i.to_le_bytes()).unwrap();
            }
            (t.stats, drain(&mut rx))
        };
        let (s1, d1) = run(77);
        let (s2, d2) = run(77);
        assert_eq!(s1, s2);
        assert_eq!(d1, d2, "same seed must deliver the identical sequence");
        let (s3, d3) = run(78);
        assert!(s1 != s3 || d1 != d3, "different seeds should diverge");
    }
}
