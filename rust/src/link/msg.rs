//! Link message types and their binary wire codec.
//!
//! The paper (§II): *"The channels carry messages that contain the
//! request and response information such as address, length, and data.
//! The structure of the messages can be easily extended to carry
//! additional customized information."* — messages here are the
//! high-level MMIO/DMA/interrupt requests; the vpcie-style baseline
//! instead carries raw PCIe TLPs in [`Msg::Tlp`] frames (see
//! `pcie::tlp`), which is exactly the related-work contrast the paper
//! draws in §V.
//!
//! Wire format (little-endian throughout):
//! `magic u16 | version u8 | kind u8 | dev u8 | seq u64 | body...`
//! Frames are length-prefixed by the transport, not here.
//!
//! `dev` is the **device id** of the endpoint the frame belongs to —
//! multi-device topologies multiplex N per-device channel sets over
//! the same rendezvous, and the id in the framing turns any cross-
//! device wiring mistake into an immediate, diagnosable link error
//! instead of silent misrouted MMIO.

use crate::{Error, Result};

/// Wire magic ("VH").
pub const MAGIC: u16 = 0x5648;
/// Codec version; bumped on any incompatible body change.
/// v2: device id added to the frame header (multi-device topologies).
pub const VERSION: u8 = 2;

/// Which end of the link a participant is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// The VMM / PCIe pseudo device side.
    Vm,
    /// The HDL simulator / PCIe simulation bridge side.
    Hdl,
}

impl Side {
    pub fn peer(self) -> Side {
        match self {
            Side::Vm => Side::Hdl,
            Side::Hdl => Side::Vm,
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            Side::Vm => "vm",
            Side::Hdl => "hdl",
        }
    }
}

/// Link abstraction level: the paper's high-level MMIO messages, or
/// the vpcie-style low-level TLP forwarding baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LinkMode {
    /// High-level memory access + interrupt requests (the paper).
    #[default]
    Mmio,
    /// Raw PCIe transaction-layer packets (vpcie baseline, §V).
    Tlp,
}

impl std::str::FromStr for LinkMode {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mmio" => Ok(LinkMode::Mmio),
            "tlp" => Ok(LinkMode::Tlp),
            other => Err(Error::config(format!("unknown link mode {other:?}"))),
        }
    }
}

/// A link message. `seq` lives in the frame header (managed by the
/// reliable channel), not here.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Msg {
    // ---- VM → HDL requests (channel pair A, request direction) ----
    /// Guest MMIO read of `len` bytes at `addr` within BAR `bar`.
    MmioRead { tag: u64, bar: u8, addr: u64, len: u32 },
    /// Guest MMIO write (posted; no response message).
    MmioWrite { bar: u8, addr: u64, data: Vec<u8> },

    // ---- HDL → VM responses (pair A, response direction) ----
    /// Completion for `MmioRead` with the matching `tag`.
    MmioReadResp { tag: u64, data: Vec<u8> },

    // ---- HDL → VM requests (pair B, request direction) ----
    /// Device DMA read from guest physical memory.
    DmaRead { tag: u64, addr: u64, len: u32 },
    /// Device DMA write to guest physical memory (posted).
    DmaWrite { addr: u64, data: Vec<u8> },
    /// MSI interrupt request for `vector`.
    Interrupt { vector: u16 },

    // ---- VM → HDL responses (pair B, response direction) ----
    /// Completion for `DmaRead` with the matching `tag`.
    DmaReadResp { tag: u64, data: Vec<u8> },

    // ---- vpcie-baseline mode: raw TLP bytes in either direction ----
    Tlp { bytes: Vec<u8> },

    // ---- control plane (reliable channel layer) ----
    /// Sent on (re)connect: identifies the sender and the last seq it
    /// has *processed* from the peer, so the peer can replay the rest.
    Hello { side_is_vm: bool, session: u64, last_seq_seen: u64 },
    /// Cumulative acknowledgement of peer seqs up to and including.
    Ack { up_to: u64 },
    /// Orderly shutdown of a side.
    Bye,
    /// Reliable-stream resume point, sent right after every [`Msg::Hello`]:
    /// `from` is the lowest seq the sender can still supply (front of
    /// its outbox, or its next fresh seq when nothing is unacked). The
    /// receiver fast-forwards its delivery watermark to `from - 1` —
    /// always safe, since every earlier seq was cumulatively acked —
    /// so a restarted receiver's strict in-order delivery cannot
    /// deadlock waiting for frames its previous incarnation consumed.
    Resume { from: u64 },
    /// Cumulative ack plus a renet-style 32-wide selective-ack window:
    /// bit `i` set ⇒ seq `up_to + 1 + i` is buffered out-of-order at
    /// the receiver, so the sender can skip retransmitting it.
    AckBits { up_to: u64, bits: u32 },
    /// Unreliable-sequenced telemetry tick (stats channel): stale ticks
    /// are dropped by the receiver, never retransmitted, never acked.
    StatTick { cycles: u64, records_done: u64 },
}

/// Kind bytes (wire stable; append-only).
mod kind {
    pub const MMIO_READ: u8 = 1;
    pub const MMIO_WRITE: u8 = 2;
    pub const MMIO_READ_RESP: u8 = 3;
    pub const DMA_READ: u8 = 4;
    pub const DMA_WRITE: u8 = 5;
    pub const INTERRUPT: u8 = 6;
    pub const DMA_READ_RESP: u8 = 7;
    pub const TLP: u8 = 8;
    pub const HELLO: u8 = 9;
    pub const ACK: u8 = 10;
    pub const BYE: u8 = 11;
    pub const RESUME: u8 = 12;
    pub const ACK_BITS: u8 = 13;
    pub const STAT_TICK: u8 = 14;
}

/// Append a `u16/u32/u64` little-endian.
fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_u32(buf, b.len() as u32);
    buf.extend_from_slice(b);
}

/// Cursor-style reader with bounds checking.
struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // `get` (not slice indexing): a truncated or corrupt frame
        // from the peer must surface as `Error::link`, never as a
        // panic in the receive hot path.
        let end = self.off.checked_add(n).ok_or_else(|| {
            Error::link(format!("frame length overflow: need {n} at {}", self.off))
        })?;
        let s = self.b.get(self.off..end).ok_or_else(|| {
            Error::link(format!(
                "truncated frame: need {n} at {}, have {}",
                self.off,
                self.b.len()
            ))
        })?;
        self.off = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?.first().copied().unwrap_or_default())
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes(
            b.try_into().map_err(|_| Error::link("u16 field width"))?,
        ))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes(
            b.try_into().map_err(|_| Error::link("u32 field width"))?,
        ))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(
            b.try_into().map_err(|_| Error::link("u64 field width"))?,
        ))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        // Cap: a DMA burst is at most a few KiB; 16 MiB is a hard
        // sanity bound against corrupt length fields.
        if n > 16 << 20 {
            return Err(Error::link(format!("frame body too large: {n}")));
        }
        Ok(self.take(n)?.to_vec())
    }
    fn done(&self) -> Result<()> {
        if self.off != self.b.len() {
            return Err(Error::link(format!(
                "trailing bytes in frame: {} of {}",
                self.b.len() - self.off,
                self.b.len()
            )));
        }
        Ok(())
    }
}

impl Msg {
    /// Encode with the frame header for device 0 (the single-device
    /// default). `seq` is the reliable-channel sequence number (0 for
    /// control messages outside the stream).
    pub fn encode(&self, seq: u64) -> Vec<u8> {
        self.encode_on(seq, 0)
    }

    /// Encode with the frame header, stamping the owning endpoint's
    /// device id (multi-device channel multiplexing).
    pub fn encode_on(&self, seq: u64, dev: u8) -> Vec<u8> {
        let mut buf = Vec::with_capacity(32);
        self.encode_into(seq, dev, &mut buf);
        buf
    }

    /// Encode into a caller-owned buffer (cleared first). The reliable
    /// channel's control plane (acks, hellos) runs on every poll, so
    /// it reuses one scratch buffer through this instead of paying a
    /// `Vec` allocation per control frame — see
    /// `channel_throughput`'s allocation notes for the enforcement.
    pub fn encode_into(&self, seq: u64, dev: u8, buf: &mut Vec<u8>) {
        buf.clear();
        put_u16(buf, MAGIC);
        buf.push(VERSION);
        buf.push(self.kind());
        buf.push(dev);
        put_u64(buf, seq);
        match self {
            Msg::MmioRead { tag, bar, addr, len } => {
                put_u64(buf, *tag);
                buf.push(*bar);
                put_u64(buf, *addr);
                put_u32(buf, *len);
            }
            Msg::MmioWrite { bar, addr, data } => {
                buf.push(*bar);
                put_u64(buf, *addr);
                put_bytes(buf, data);
            }
            Msg::MmioReadResp { tag, data } => {
                put_u64(buf, *tag);
                put_bytes(buf, data);
            }
            Msg::DmaRead { tag, addr, len } => {
                put_u64(buf, *tag);
                put_u64(buf, *addr);
                put_u32(buf, *len);
            }
            Msg::DmaWrite { addr, data } => {
                put_u64(buf, *addr);
                put_bytes(buf, data);
            }
            Msg::Interrupt { vector } => {
                put_u16(buf, *vector);
            }
            Msg::DmaReadResp { tag, data } => {
                put_u64(buf, *tag);
                put_bytes(buf, data);
            }
            Msg::Tlp { bytes } => {
                put_bytes(buf, bytes);
            }
            Msg::Hello { side_is_vm, session, last_seq_seen } => {
                buf.push(*side_is_vm as u8);
                put_u64(buf, *session);
                put_u64(buf, *last_seq_seen);
            }
            Msg::Ack { up_to } => {
                put_u64(buf, *up_to);
            }
            Msg::Bye => {}
            Msg::Resume { from } => {
                put_u64(buf, *from);
            }
            Msg::AckBits { up_to, bits } => {
                put_u64(buf, *up_to);
                put_u32(buf, *bits);
            }
            Msg::StatTick { cycles, records_done } => {
                put_u64(buf, *cycles);
                put_u64(buf, *records_done);
            }
        }
    }

    /// Decode a frame; returns `(seq, msg)`, discarding the device id
    /// (single-device callers).
    pub fn decode(frame: &[u8]) -> Result<(u64, Msg)> {
        let (seq, _dev, msg) = Self::decode_on(frame)?;
        Ok((seq, msg))
    }

    /// Decode a frame; returns `(seq, device_id, msg)`.
    pub fn decode_on(frame: &[u8]) -> Result<(u64, u8, Msg)> {
        let mut r = Rd { b: frame, off: 0 };
        let magic = r.u16()?;
        if magic != MAGIC {
            return Err(Error::link(format!("bad magic {magic:#06x}")));
        }
        let ver = r.u8()?;
        if ver != VERSION {
            return Err(Error::link(format!("codec version {ver} != {VERSION}")));
        }
        let kind = r.u8()?;
        let dev = r.u8()?;
        let seq = r.u64()?;
        let msg = match kind {
            kind::MMIO_READ => Msg::MmioRead {
                tag: r.u64()?,
                bar: r.u8()?,
                addr: r.u64()?,
                len: r.u32()?,
            },
            kind::MMIO_WRITE => Msg::MmioWrite {
                bar: r.u8()?,
                addr: r.u64()?,
                data: r.bytes()?,
            },
            kind::MMIO_READ_RESP => Msg::MmioReadResp {
                tag: r.u64()?,
                data: r.bytes()?,
            },
            kind::DMA_READ => Msg::DmaRead {
                tag: r.u64()?,
                addr: r.u64()?,
                len: r.u32()?,
            },
            kind::DMA_WRITE => Msg::DmaWrite {
                addr: r.u64()?,
                data: r.bytes()?,
            },
            kind::INTERRUPT => Msg::Interrupt { vector: r.u16()? },
            kind::DMA_READ_RESP => Msg::DmaReadResp {
                tag: r.u64()?,
                data: r.bytes()?,
            },
            kind::TLP => Msg::Tlp { bytes: r.bytes()? },
            kind::HELLO => Msg::Hello {
                // Strictly 0/1 so every accepted frame re-encodes
                // byte-identically (the fuzz harness pins this).
                side_is_vm: match r.u8()? {
                    0 => false,
                    1 => true,
                    other => {
                        return Err(Error::link(format!("hello side byte {other}")))
                    }
                },
                session: r.u64()?,
                last_seq_seen: r.u64()?,
            },
            kind::ACK => Msg::Ack { up_to: r.u64()? },
            kind::BYE => Msg::Bye,
            kind::RESUME => Msg::Resume { from: r.u64()? },
            kind::ACK_BITS => Msg::AckBits {
                up_to: r.u64()?,
                bits: r.u32()?,
            },
            kind::STAT_TICK => Msg::StatTick {
                cycles: r.u64()?,
                records_done: r.u64()?,
            },
            other => return Err(Error::link(format!("unknown kind {other}"))),
        };
        r.done()?;
        Ok((seq, dev, msg))
    }

    fn kind(&self) -> u8 {
        match self {
            Msg::MmioRead { .. } => kind::MMIO_READ,
            Msg::MmioWrite { .. } => kind::MMIO_WRITE,
            Msg::MmioReadResp { .. } => kind::MMIO_READ_RESP,
            Msg::DmaRead { .. } => kind::DMA_READ,
            Msg::DmaWrite { .. } => kind::DMA_WRITE,
            Msg::Interrupt { .. } => kind::INTERRUPT,
            Msg::DmaReadResp { .. } => kind::DMA_READ_RESP,
            Msg::Tlp { .. } => kind::TLP,
            Msg::Hello { .. } => kind::HELLO,
            Msg::Ack { .. } => kind::ACK,
            Msg::Bye => kind::BYE,
            Msg::Resume { .. } => kind::RESUME,
            Msg::AckBits { .. } => kind::ACK_BITS,
            Msg::StatTick { .. } => kind::STAT_TICK,
        }
    }

    /// True for control-plane messages that bypass the reliable stream.
    pub fn is_control(&self) -> bool {
        matches!(
            self,
            Msg::Hello { .. }
                | Msg::Ack { .. }
                | Msg::Bye
                | Msg::Resume { .. }
                | Msg::AckBits { .. }
        )
    }

    /// True for payloads on the unreliable-sequenced channel: delivered
    /// best-effort, stale ones dropped, never acked or retransmitted.
    pub fn is_unreliable(&self) -> bool {
        matches!(self, Msg::StatTick { .. })
    }

    /// Short human label for logs/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            Msg::MmioRead { .. } => "mmio_read",
            Msg::MmioWrite { .. } => "mmio_write",
            Msg::MmioReadResp { .. } => "mmio_read_resp",
            Msg::DmaRead { .. } => "dma_read",
            Msg::DmaWrite { .. } => "dma_write",
            Msg::Interrupt { .. } => "interrupt",
            Msg::DmaReadResp { .. } => "dma_read_resp",
            Msg::Tlp { .. } => "tlp",
            Msg::Hello { .. } => "hello",
            Msg::Ack { .. } => "ack",
            Msg::Bye => "bye",
            Msg::Resume { .. } => "resume",
            Msg::AckBits { .. } => "ack_bits",
            Msg::StatTick { .. } => "stat_tick",
        }
    }

    /// Encoded payload size (for the §V message-volume comparison).
    pub fn wire_len(&self) -> usize {
        self.encode(0).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, XorShift64};

    fn sample_msgs() -> Vec<Msg> {
        vec![
            Msg::MmioRead { tag: 7, bar: 0, addr: 0x1000, len: 4 },
            Msg::MmioWrite { bar: 1, addr: 0x20, data: vec![1, 2, 3, 4] },
            Msg::MmioReadResp { tag: 7, data: vec![0xde, 0xad] },
            Msg::DmaRead { tag: 99, addr: 0x8000_0000, len: 4096 },
            Msg::DmaWrite { addr: 0x8000_1000, data: vec![0; 64] },
            Msg::Interrupt { vector: 3 },
            Msg::DmaReadResp { tag: 99, data: vec![5; 16] },
            Msg::Tlp { bytes: vec![0x40, 0, 0, 1] },
            Msg::Hello { side_is_vm: true, session: 42, last_seq_seen: 17 },
            Msg::Ack { up_to: 1234 },
            Msg::Bye,
            Msg::Resume { from: 51 },
            Msg::AckBits { up_to: 90, bits: 0b1011 },
            Msg::StatTick { cycles: 123_456, records_done: 789 },
        ]
    }

    #[test]
    fn roundtrip_all_kinds() {
        for (i, m) in sample_msgs().into_iter().enumerate() {
            let f = m.encode(i as u64);
            let (seq, back) = Msg::decode(&f).unwrap();
            assert_eq!(seq, i as u64);
            assert_eq!(back, m);
        }
    }

    #[test]
    fn device_id_roundtrips_in_header() {
        for dev in [0u8, 1, 3, 255] {
            let f = Msg::MmioRead { tag: 1, bar: 0, addr: 2, len: 4 }.encode_on(9, dev);
            let (seq, got_dev, msg) = Msg::decode_on(&f).unwrap();
            assert_eq!((seq, got_dev), (9, dev));
            assert!(matches!(msg, Msg::MmioRead { tag: 1, .. }));
        }
        // The single-device encode stamps device 0.
        let f = Msg::Bye.encode(0);
        assert_eq!(Msg::decode_on(&f).unwrap().1, 0);
    }

    #[test]
    fn control_and_unreliable_classification() {
        // Exactly the reliability-layer control frames are control...
        for m in sample_msgs() {
            let ctrl = matches!(
                m,
                Msg::Hello { .. }
                    | Msg::Ack { .. }
                    | Msg::Bye
                    | Msg::Resume { .. }
                    | Msg::AckBits { .. }
            );
            assert_eq!(m.is_control(), ctrl, "{}", m.label());
            // ...and nothing is both control and unreliable payload.
            assert!(!(m.is_control() && m.is_unreliable()), "{}", m.label());
        }
        assert!(Msg::StatTick { cycles: 1, records_done: 0 }.is_unreliable());
        assert!(!Msg::Interrupt { vector: 0 }.is_unreliable());
    }

    #[test]
    fn rejects_bad_magic_version_kind() {
        let f = Msg::Bye.encode(0);
        let mut bad = f.clone();
        bad[0] ^= 0xff;
        assert!(Msg::decode(&bad).is_err());
        let mut bad = f.clone();
        bad[2] = 200;
        assert!(Msg::decode(&bad).is_err());
        let mut bad = f;
        bad[3] = 250;
        assert!(Msg::decode(&bad).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let f = Msg::MmioRead { tag: 1, bar: 0, addr: 2, len: 3 }.encode(9);
        for cut in 1..f.len() {
            assert!(Msg::decode(&f[..cut]).is_err(), "cut={cut}");
        }
        let mut long = f;
        long.push(0);
        assert!(Msg::decode(&long).is_err());
    }

    #[test]
    fn rejects_absurd_length_field() {
        let mut f = Msg::MmioWrite { bar: 0, addr: 0, data: vec![1] }.encode(0);
        // Patch the 4-byte data length (last 5 bytes are len+data).
        let n = f.len();
        f[n - 5..n - 1].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Msg::decode(&f).is_err());
    }

    #[test]
    fn prop_roundtrip_random_payloads() {
        forall(
            0xC0DE,
            300,
            |g| {
                let n = g.size(2048);
                let kind = g.rng.range(0, 3);
                let data = g.rng.vec_u8(n);
                match kind {
                    0 => Msg::MmioWrite { bar: g.rng.range(0, 5) as u8, addr: g.rng.next_u64(), data },
                    1 => Msg::DmaWrite { addr: g.rng.next_u64(), data },
                    2 => Msg::DmaReadResp { tag: g.rng.next_u64(), data },
                    _ => Msg::Tlp { bytes: data },
                }
            },
            |m| {
                let seq = 0x1234_5678_9abc_def0;
                let (s, back) = Msg::decode(&m.encode(seq)).map_err(|e| e.to_string())?;
                if s != seq {
                    return Err("seq mangled".into());
                }
                if &back != m {
                    return Err("message mangled".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_decode_never_panics_on_noise() {
        forall(
            0xF00D,
            500,
            |g| {
                let n = g.size(256);
                let mut v = g.rng.vec_u8(n);
                // Half the cases: start from a valid frame and corrupt.
                if g.rng.chance(1, 2) {
                    let mut r = XorShift64::new(g.rng.next_u64());
                    let f = Msg::DmaRead { tag: 1, addr: 2, len: 3 }.encode(4);
                    v = f;
                    let i = r.range(0, v.len() - 1);
                    v[i] ^= 1 << r.range(0, 7);
                }
                v
            },
            |bytes| {
                let _ = Msg::decode(bytes); // must not panic
                Ok(())
            },
        );
    }
}
