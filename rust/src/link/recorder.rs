//! Record/replay frame log: a transport decorator that taps every
//! frame crossing the HDL endpoint into a versioned, length-prefixed
//! binary log (`run.vhrec`), plus the pure codec for that format.
//!
//! The tap sits at the **raw transport** level, below the reliable
//! channel — so the log captures exactly what the wire carried:
//! handshakes, acks, retransmits, duplicated/corrupted frames from an
//! impaired peer, everything. Since PR 1 device cycle counts are a
//! pure function of the delivered message sequence, the guest→device
//! half of this log is a complete, VM-free reproduction recipe for
//! the run: `coordinator::replay` feeds it back into fresh HDL lanes
//! and asserts the device→guest bytes and final cycle counts match.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header:  magic "VHRC" | version u16 | seed u64 | scenario str
//!          | git str | impair str | device_count u32 | DeviceMeta…
//! event:   tag u8 = 1 | dir u8 | device u8 | chan u8 | len u32 | bytes
//! trailer: tag u8 = 2 | device_count u32 | (cycles u64, records u64)…
//! str:     len u32 | utf-8 bytes
//! ```
//!
//! A finalized log ends with exactly one trailer; a log from a run
//! that died early is *partial* (no trailer) but still event-aligned:
//! the sink only ever buffers whole events and flushes them on drop,
//! so an error path never leaves a torn frame mid-file.
//!
//! Decoding is fully bounds-checked and never panics: this file is in
//! the `cargo xtask analyze` panic-audit scope, and the fuzz suite
//! (`rust/tests/recording_fuzz.rs`) mutates encoded logs to hold the
//! "structured error, never a panic" line.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

use super::transport::{Doorbell, Transport};
use crate::{Error, Result};

/// Log file magic ("VHRC": VM-HDL ReCording).
pub const REC_MAGIC: [u8; 4] = *b"VHRC";
/// Current log format version; bump on any layout change.
/// v2 appends a per-device fault-plan string to [`DeviceMeta`] so a
/// recorded fault-injection run replays bit-identically; v1 logs are
/// still decodable (fault = "").
pub const REC_VERSION: u16 = 2;
/// File name of the frame log inside a recording directory.
pub const REC_FILE: &str = "run.vhrec";

const TAG_FRAME: u8 = 1;
const TAG_TRAILER: u8 = 2;
/// Upper bound on a single logged frame (wire frames are < 64 KiB;
/// the slack keeps the bound from ever being the thing that breaks).
pub const MAX_FRAME_LEN: usize = 1 << 24;
const MAX_STR_LEN: usize = 1 << 16;
const MAX_DEVICES: usize = 256;

/// Direction of a logged frame, relative to the recorded HDL side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// VM/guest → device: the replay *schedule* (re-injected verbatim).
    GuestToDevice,
    /// Device → guest: the replay *oracle* (compared byte-for-byte).
    DeviceToGuest,
}

impl Dir {
    fn tag(self) -> u8 {
        match self {
            Dir::GuestToDevice => 0,
            Dir::DeviceToGuest => 1,
        }
    }

    fn from_tag(t: u8) -> Result<Dir> {
        match t {
            0 => Ok(Dir::GuestToDevice),
            1 => Ok(Dir::DeviceToGuest),
            other => Err(Error::link(format!(
                "recording: unknown direction tag {other}"
            ))),
        }
    }
}

/// Per-device elaboration parameters, enough for the replay driver to
/// rebuild a cycle-identical `Platform` without the original CLI.
/// Kernel kind and link mode travel as their `FromStr` spellings so
/// the link layer stays independent of `hdl::` types.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct DeviceMeta {
    pub kernel: String,
    pub n: u64,
    pub latency: u64,
    pub pipeline_records: u64,
    pub link_mode: String,
    pub bram_size: u64,
    pub stream_fifo_depth: u64,
    pub poll_interval: u64,
    pub device_index: u64,
    /// Impairment summary for this device ("" = clean link). Replay
    /// only needs the presence bit (loss tolerance); the text is for
    /// humans reading the header.
    pub impair: String,
    /// PCIe fault plan armed on this device ("" = none), in
    /// [`crate::pcie::FaultPlan`] spelling (`poisoned-cpl@rec=5`).
    /// Replay parses it back so HDL-side fault behaviour (and the
    /// snapshot geometry stamp) matches the recorded run. v2+.
    pub fault: String,
}

/// Run-level metadata written into the log header.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct RecordMeta {
    /// Workload seed of the recorded run (metadata only — replay does
    /// not re-generate the workload, it re-injects recorded frames).
    pub seed: u64,
    /// Human description of the recorded scenario/CLI invocation.
    pub scenario: String,
    /// `git describe --always --dirty` of the recording build.
    pub git: String,
    /// Global impairment summary ("" = clean links).
    pub impair: String,
    pub devices: Vec<DeviceMeta>,
}

/// One logged frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameEvent {
    pub dir: Dir,
    pub device: u8,
    /// 0 = pair A (VM-initiated MMIO), 1 = pair B (HDL-initiated DMA/IRQ).
    pub chan: u8,
    pub bytes: Vec<u8>,
}

/// Per-device final state written by the trailer on clean shutdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DeviceFinal {
    pub cycles: u64,
    pub records_done: u64,
}

/// A fully decoded log.
#[derive(Debug, Clone)]
pub struct Recording {
    pub meta: RecordMeta,
    pub events: Vec<FrameEvent>,
    /// Present iff the run shut down cleanly (trailer written).
    pub trailer: Option<Vec<DeviceFinal>>,
    /// True if decoding stopped at a truncated tail (allowed only via
    /// `allow_partial` — crash logs are usable, silently-short ones
    /// are not).
    pub partial: bool,
}

// ------------------------------------------------------------- encode

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    let chunk = b.get(..b.len().min(MAX_STR_LEN)).unwrap_or(b);
    put_u32(out, chunk.len() as u32);
    out.extend_from_slice(chunk);
}

/// Encode the log header for `meta`.
pub fn encode_header(meta: &RecordMeta) -> Vec<u8> {
    let mut out = Vec::with_capacity(256);
    out.extend_from_slice(&REC_MAGIC);
    put_u16(&mut out, REC_VERSION);
    put_u64(&mut out, meta.seed);
    put_str(&mut out, &meta.scenario);
    put_str(&mut out, &meta.git);
    put_str(&mut out, &meta.impair);
    put_u32(&mut out, meta.devices.len() as u32);
    for d in &meta.devices {
        put_str(&mut out, &d.kernel);
        put_u64(&mut out, d.n);
        put_u64(&mut out, d.latency);
        put_u64(&mut out, d.pipeline_records);
        put_str(&mut out, &d.link_mode);
        put_u64(&mut out, d.bram_size);
        put_u64(&mut out, d.stream_fifo_depth);
        put_u64(&mut out, d.poll_interval);
        put_u64(&mut out, d.device_index);
        put_str(&mut out, &d.impair);
        put_str(&mut out, &d.fault);
    }
    out
}

/// Append one frame event to `out`.
pub fn encode_frame(dir: Dir, device: u8, chan: u8, frame: &[u8], out: &mut Vec<u8>) {
    out.push(TAG_FRAME);
    out.push(dir.tag());
    out.push(device);
    out.push(chan);
    put_u32(out, frame.len() as u32);
    out.extend_from_slice(frame);
}

/// Append the trailer to `out`.
pub fn encode_trailer(finals: &[DeviceFinal], out: &mut Vec<u8>) {
    out.push(TAG_TRAILER);
    put_u32(out, finals.len() as u32);
    for f in finals {
        put_u64(out, f.cycles);
        put_u64(out, f.records_done);
    }
}

// ------------------------------------------------------------- decode

/// Bounds-checked little-endian reader over the raw log bytes. Every
/// getter names what it was reading so a truncation error pinpoints
/// the field, not just an offset.
struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, off: 0 }
    }

    fn eof(&self) -> bool {
        self.off >= self.b.len()
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.off.checked_add(n).ok_or_else(|| {
            Error::link(format!("recording: length overflow reading {what}"))
        })?;
        let s = self.b.get(self.off..end).ok_or_else(|| {
            Error::link(format!(
                "recording: truncated at byte {} reading {what} ({} of {} bytes left)",
                self.off,
                self.b.len().saturating_sub(self.off),
                n
            ))
        })?;
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?.first().copied().unwrap_or(0))
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let s = self.take(2, what)?;
        let mut a = [0u8; 2];
        for (d, v) in a.iter_mut().zip(s) {
            *d = *v;
        }
        Ok(u16::from_le_bytes(a))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let s = self.take(4, what)?;
        let mut a = [0u8; 4];
        for (d, v) in a.iter_mut().zip(s) {
            *d = *v;
        }
        Ok(u32::from_le_bytes(a))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let s = self.take(8, what)?;
        let mut a = [0u8; 8];
        for (d, v) in a.iter_mut().zip(s) {
            *d = *v;
        }
        Ok(u64::from_le_bytes(a))
    }

    fn str_(&mut self, what: &str) -> Result<String> {
        let n = self.u32(what)? as usize;
        if n > MAX_STR_LEN {
            return Err(Error::link(format!(
                "recording: string length {n} for {what} exceeds {MAX_STR_LEN}"
            )));
        }
        let s = self.take(n, what)?;
        String::from_utf8(s.to_vec()).map_err(|_| {
            Error::link(format!("recording: {what} is not valid utf-8"))
        })
    }
}

fn decode_header(r: &mut Rd) -> Result<RecordMeta> {
    let magic = r.take(4, "magic")?;
    if magic != REC_MAGIC {
        return Err(Error::link(format!(
            "recording: bad magic {magic:02x?} (expected {REC_MAGIC:02x?})"
        )));
    }
    let ver = r.u16("version")?;
    if ver == 0 || ver > REC_VERSION {
        return Err(Error::link(format!(
            "recording: unsupported version {ver} (this build reads 1..={REC_VERSION})"
        )));
    }
    let seed = r.u64("seed")?;
    let scenario = r.str_("scenario")?;
    let git = r.str_("git")?;
    let impair = r.str_("impair")?;
    let ndev = r.u32("device count")? as usize;
    if ndev == 0 || ndev > MAX_DEVICES {
        return Err(Error::link(format!(
            "recording: implausible device count {ndev}"
        )));
    }
    let mut devices = Vec::with_capacity(ndev);
    for k in 0..ndev {
        devices.push(DeviceMeta {
            kernel: r.str_("device kernel")?,
            n: r.u64("device n")?,
            latency: r.u64("device latency")?,
            pipeline_records: r.u64("device pipeline_records")?,
            link_mode: r.str_("device link_mode")?,
            bram_size: r.u64("device bram_size")?,
            stream_fifo_depth: r.u64("device stream_fifo_depth")?,
            poll_interval: r.u64("device poll_interval")?,
            device_index: r.u64("device index")?,
            impair: r.str_("device impair")?,
            // v1 logs predate fault injection: no plan was armed.
            fault: if ver >= 2 { r.str_("device fault")? } else { String::new() },
        });
        let got = devices.last().map(|d| d.device_index).unwrap_or(0);
        if got != k as u64 {
            return Err(Error::link(format!(
                "recording: device {k} header carries index {got}"
            )));
        }
    }
    Ok(RecordMeta { seed, scenario, git, impair, devices })
}

enum Event {
    Frame(FrameEvent),
    Trailer(Vec<DeviceFinal>),
}

fn decode_event(r: &mut Rd, ndev: usize) -> Result<Event> {
    match r.u8("event tag")? {
        TAG_FRAME => {
            let dir = Dir::from_tag(r.u8("frame direction")?)?;
            let device = r.u8("frame device")?;
            if usize::from(device) >= ndev {
                return Err(Error::link(format!(
                    "recording: frame for device {device} but header declares {ndev}"
                )));
            }
            let chan = r.u8("frame channel")?;
            if chan > 1 {
                return Err(Error::link(format!(
                    "recording: frame channel {chan} (only pairs A=0/B=1 exist)"
                )));
            }
            let len = r.u32("frame length")? as usize;
            if len > MAX_FRAME_LEN {
                return Err(Error::link(format!(
                    "recording: frame length {len} exceeds {MAX_FRAME_LEN}"
                )));
            }
            let bytes = r.take(len, "frame bytes")?.to_vec();
            Ok(Event::Frame(FrameEvent { dir, device, chan, bytes }))
        }
        TAG_TRAILER => {
            let n = r.u32("trailer device count")? as usize;
            if n != ndev {
                return Err(Error::link(format!(
                    "recording: trailer covers {n} devices, header declares {ndev}"
                )));
            }
            let mut finals = Vec::with_capacity(n);
            for _ in 0..n {
                finals.push(DeviceFinal {
                    cycles: r.u64("trailer cycles")?,
                    records_done: r.u64("trailer records")?,
                });
            }
            Ok(Event::Trailer(finals))
        }
        other => Err(Error::link(format!(
            "recording: unknown event tag {other} at byte {}",
            r.off.saturating_sub(1)
        ))),
    }
}

/// Decode a complete log. With `allow_partial`, a truncated tail (a
/// run that died before writing its trailer, or mid-event on a hard
/// kill) yields the decodable prefix with `partial = true`; without
/// it, truncation is an error. Corruption *before* the tail — bad
/// magic, unknown tags, bytes after the trailer — is always an error.
pub fn decode_recording(bytes: &[u8], allow_partial: bool) -> Result<Recording> {
    let mut r = Rd::new(bytes);
    let meta = decode_header(&mut r)?;
    let ndev = meta.devices.len();
    let mut events = Vec::new();
    let mut trailer: Option<Vec<DeviceFinal>> = None;
    let mut partial = false;
    while !r.eof() {
        if trailer.is_some() {
            return Err(Error::link(format!(
                "recording: {} trailing bytes after the trailer",
                bytes.len().saturating_sub(r.off)
            )));
        }
        match decode_event(&mut r, ndev) {
            Ok(Event::Frame(f)) => events.push(f),
            Ok(Event::Trailer(t)) => trailer = Some(t),
            Err(e) => {
                if allow_partial {
                    partial = true;
                    break;
                }
                return Err(e);
            }
        }
    }
    if trailer.is_none() && !allow_partial {
        return Err(Error::link(
            "recording: no trailer (run did not shut down cleanly); \
             pass allow_partial to replay the prefix",
        ));
    }
    if trailer.is_none() {
        partial = true;
    }
    Ok(Recording { meta, events, trailer, partial })
}

/// Read and decode `dir/run.vhrec` (or `dir` itself if it is a file).
pub fn read_recording(dir: &Path, allow_partial: bool) -> Result<Recording> {
    let path = if dir.is_file() { dir.to_path_buf() } else { dir.join(REC_FILE) };
    let bytes = std::fs::read(&path).map_err(|e| {
        Error::link(format!("recording: cannot read {}: {e}", path.display()))
    })?;
    decode_recording(&bytes, allow_partial)
}

/// Best-effort `git describe --always --dirty` for the header.
pub fn git_describe() -> String {
    let out = std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output();
    match out {
        Ok(o) if o.status.success() => {
            String::from_utf8_lossy(&o.stdout).trim().to_string()
        }
        _ => "unknown".to_string(),
    }
}

// --------------------------------------------------------------- sink

/// Shared state behind a [`RecorderSink`]. All taps of one run write
/// through one instance, and all tap calls happen on the one HDL
/// thread — so the log is a totally ordered, causally consistent view
/// of the run's link traffic.
struct RecInner {
    out: Option<std::io::BufWriter<std::fs::File>>,
    path: PathBuf,
    /// Reused event staging buffer (one whole event per write, so a
    /// flush can never leave a torn frame mid-file).
    buf: Vec<u8>,
    frames: u64,
    payload_bytes: u64,
    finished: bool,
    /// First write error, if any (recording must never take down the
    /// run it is observing — errors are latched and surfaced at
    /// finish time).
    error: Option<String>,
}

impl RecInner {
    fn write_event(&mut self, event: &[u8]) {
        let Some(out) = self.out.as_mut() else {
            return;
        };
        if let Err(e) = out.write_all(event) {
            self.error = Some(format!("write {}: {e}", self.path.display()));
            self.out = None;
        }
    }

    fn flush(&mut self) {
        if let Some(out) = self.out.as_mut() {
            if let Err(e) = out.flush() {
                self.error = Some(format!("flush {}: {e}", self.path.display()));
                self.out = None;
            }
        }
    }
}

impl Drop for RecInner {
    /// Error-path insurance: if the run dies before `finish`, flush
    /// whatever complete events are buffered so the partial log on
    /// disk is still decodable (`allow_partial`) — no truncated
    /// recordings on the error path.
    fn drop(&mut self) {
        self.flush();
    }
}

/// Cloneable handle to one run's frame log. Clones share the file;
/// one clone goes into each [`RecordingTransport`] tap and one stays
/// with the run handle to write the trailer at shutdown.
#[derive(Clone)]
pub struct RecorderSink {
    inner: Arc<Mutex<RecInner>>,
}

impl RecorderSink {
    /// Create `dir/run.vhrec` and write the header.
    pub fn create(dir: &Path, meta: &RecordMeta) -> Result<RecorderSink> {
        std::fs::create_dir_all(dir).map_err(|e| {
            Error::link(format!("recording: create {}: {e}", dir.display()))
        })?;
        let path = dir.join(REC_FILE);
        let f = std::fs::File::create(&path).map_err(|e| {
            Error::link(format!("recording: create {}: {e}", path.display()))
        })?;
        let mut out = std::io::BufWriter::new(f);
        out.write_all(&encode_header(meta)).map_err(|e| {
            Error::link(format!("recording: write header {}: {e}", path.display()))
        })?;
        Ok(RecorderSink {
            inner: Arc::new(Mutex::new(RecInner {
                out: Some(out),
                path,
                buf: Vec::with_capacity(256),
                frames: 0,
                payload_bytes: 0,
                finished: false,
                error: None,
            })),
        })
    }

    /// Ride through poisoning: a tap on a panicked lane must not
    /// cascade a second panic out of the recorder (the inner state
    /// stays structurally valid under every partial update).
    fn lock(&self) -> MutexGuard<'_, RecInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Append one frame event. Infallible by design: a full disk must
    /// not fail the co-sim run — the error is latched and reported by
    /// [`RecorderSink::finish`].
    pub fn log_frame(&self, dir: Dir, device: u8, chan: u8, frame: &[u8]) {
        let mut g = self.lock();
        if g.finished || g.out.is_none() {
            return;
        }
        if frame.len() > MAX_FRAME_LEN {
            g.error = Some(format!(
                "frame of {} bytes exceeds MAX_FRAME_LEN",
                frame.len()
            ));
            g.out = None;
            return;
        }
        let mut buf = std::mem::take(&mut g.buf);
        buf.clear();
        encode_frame(dir, device, chan, frame, &mut buf);
        g.write_event(&buf);
        g.buf = buf;
        g.frames += 1;
        g.payload_bytes += frame.len() as u64;
    }

    /// Write the trailer (per-device final cycles/records) and flush.
    /// Returns the log path; surfaces any latched write error.
    pub fn finish(&self, finals: &[DeviceFinal]) -> Result<PathBuf> {
        let mut g = self.lock();
        if !g.finished {
            let mut buf = std::mem::take(&mut g.buf);
            buf.clear();
            encode_trailer(finals, &mut buf);
            g.write_event(&buf);
            g.buf = buf;
            g.flush();
            g.finished = true;
        }
        if let Some(e) = g.error.as_ref() {
            return Err(Error::link(format!("recording failed: {e}")));
        }
        Ok(g.path.clone())
    }

    /// Flush without a trailer (error-path shutdown): the log stays a
    /// decodable partial recording.
    pub fn abort(&self) {
        let mut g = self.lock();
        g.flush();
        g.finished = true;
    }

    /// Path of the log file.
    pub fn path(&self) -> PathBuf {
        self.lock().path.clone()
    }

    /// Frames logged so far.
    pub fn frames(&self) -> u64 {
        self.lock().frames
    }

    /// First latched write error, if any.
    pub fn error(&self) -> Option<String> {
        self.lock().error.clone()
    }
}

// ---------------------------------------------------------------- tap

/// Transport decorator that logs every frame through it (same shape
/// as [`super::impair::ImpairedTransport`]). Installed on the **HDL**
/// endpoint's four transports, so `send` is device→guest and receive
/// is guest→device. On the transmit direction the tap wraps
/// *outermost* — an impaired inner transport drops/corrupts *after*
/// the tap, so the log keeps the well-formed pre-impairment frame the
/// device actually produced (what replay must reproduce).
pub struct RecordingTransport {
    inner: Box<dyn Transport>,
    sink: RecorderSink,
    device: u8,
    chan: u8,
}

impl RecordingTransport {
    pub fn new(
        inner: Box<dyn Transport>,
        sink: RecorderSink,
        device: u8,
        chan: u8,
    ) -> Self {
        Self { inner, sink, device, chan }
    }
}

impl Transport for RecordingTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.sink
            .log_frame(Dir::DeviceToGuest, self.device, self.chan, frame);
        self.inner.send(frame)
    }

    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        let f = self.inner.try_recv()?;
        if let Some(fr) = f.as_ref() {
            self.sink
                .log_frame(Dir::GuestToDevice, self.device, self.chan, fr);
        }
        Ok(f)
    }

    fn try_recv_into(&mut self, out: &mut Vec<u8>) -> Result<bool> {
        if self.inner.try_recv_into(out)? {
            self.sink
                .log_frame(Dir::GuestToDevice, self.device, self.chan, out);
            return Ok(true);
        }
        Ok(false)
    }

    fn recv_timeout(&mut self, timeout: std::time::Duration) -> Result<Option<Vec<u8>>> {
        let f = self.inner.recv_timeout(timeout)?;
        if let Some(fr) = f.as_ref() {
            self.sink
                .log_frame(Dir::GuestToDevice, self.device, self.chan, fr);
        }
        Ok(f)
    }

    fn ready(&mut self) -> Result<bool> {
        self.inner.ready()
    }

    fn set_doorbell(&mut self, db: Arc<Doorbell>) {
        self.inner.set_doorbell(db);
    }

    fn peek_reconnected(&self) -> bool {
        self.inner.peek_reconnected()
    }

    fn connected(&self) -> bool {
        self.inner.connected()
    }

    fn reconnect(&mut self) -> Result<bool> {
        self.inner.reconnect()
    }

    fn take_reconnected(&mut self) -> bool {
        self.inner.take_reconnected()
    }

    fn lossy(&self) -> bool {
        self.inner.lossy()
    }

    fn label(&self) -> &'static str {
        "record"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::link::transport::make_inproc_pair;

    fn meta2() -> RecordMeta {
        RecordMeta {
            seed: 42,
            scenario: "test scenario".into(),
            git: "deadbeef-dirty".into(),
            impair: "drop=0.05".into(),
            devices: (0..2)
                .map(|k| DeviceMeta {
                    kernel: "sort".into(),
                    n: 1024,
                    latency: 1256,
                    pipeline_records: 8,
                    link_mode: "mmio".into(),
                    bram_size: 65536,
                    stream_fifo_depth: 64,
                    poll_interval: 1,
                    device_index: k,
                    impair: if k == 0 { String::new() } else { "dup=0.1".into() },
                    fault: if k == 0 {
                        "completion-timeout@rec=3".into()
                    } else {
                        String::new()
                    },
                })
                .collect(),
        }
    }

    fn sample_log(meta: &RecordMeta, with_trailer: bool) -> Vec<u8> {
        let mut b = encode_header(meta);
        encode_frame(Dir::GuestToDevice, 0, 0, b"\x48\x56req", &mut b);
        encode_frame(Dir::DeviceToGuest, 0, 0, b"\x48\x56resp", &mut b);
        encode_frame(Dir::GuestToDevice, 1, 1, b"", &mut b);
        if with_trailer {
            encode_trailer(
                &[
                    DeviceFinal { cycles: 1000, records_done: 3 },
                    DeviceFinal { cycles: 7, records_done: 0 },
                ],
                &mut b,
            );
        }
        b
    }

    #[test]
    fn header_and_events_roundtrip() {
        let meta = meta2();
        let rec = decode_recording(&sample_log(&meta, true), false).unwrap();
        assert_eq!(rec.meta, meta);
        assert_eq!(rec.events.len(), 3);
        assert_eq!(rec.events[0].dir, Dir::GuestToDevice);
        assert_eq!(rec.events[1].bytes, b"\x48\x56resp");
        assert_eq!(rec.events[2].device, 1);
        assert_eq!(rec.events[2].chan, 1);
        let finals = rec.trailer.unwrap();
        assert_eq!(finals[0], DeviceFinal { cycles: 1000, records_done: 3 });
        assert!(!rec.partial);
    }

    #[test]
    fn missing_trailer_needs_allow_partial() {
        let b = sample_log(&meta2(), false);
        let err = decode_recording(&b, false).unwrap_err().to_string();
        assert!(err.contains("no trailer"), "{err}");
        let rec = decode_recording(&b, true).unwrap();
        assert!(rec.partial);
        assert!(rec.trailer.is_none());
        assert_eq!(rec.events.len(), 3);
    }

    #[test]
    fn truncated_tail_decodes_partial_prefix() {
        let full = sample_log(&meta2(), false);
        // Chop mid-way through the last event.
        let cut = &full[..full.len() - 1];
        assert!(decode_recording(cut, false).is_err());
        let rec = decode_recording(cut, true).unwrap();
        assert!(rec.partial);
        assert_eq!(rec.events.len(), 2, "whole prefix events survive");
    }

    #[test]
    fn v1_header_decodes_with_no_fault_plan() {
        // Hand-encode a v1 header (no per-device fault string): old
        // logs must keep decoding, with fault defaulting to "".
        let mut b = Vec::new();
        b.extend_from_slice(&REC_MAGIC);
        put_u16(&mut b, 1);
        put_u64(&mut b, 7);
        put_str(&mut b, "legacy");
        put_str(&mut b, "cafe");
        put_str(&mut b, "");
        put_u32(&mut b, 1);
        put_str(&mut b, "sort");
        put_u64(&mut b, 1024);
        put_u64(&mut b, 1256);
        put_u64(&mut b, 8);
        put_str(&mut b, "mmio");
        put_u64(&mut b, 65536);
        put_u64(&mut b, 64);
        put_u64(&mut b, 1);
        put_u64(&mut b, 0);
        put_str(&mut b, "");
        encode_trailer(&[DeviceFinal { cycles: 9, records_done: 1 }], &mut b);
        let rec = decode_recording(&b, false).unwrap();
        assert_eq!(rec.meta.devices.len(), 1);
        assert_eq!(rec.meta.devices[0].fault, "");
        assert_eq!(rec.meta.devices[0].kernel, "sort");
    }

    #[test]
    fn version_bump_rejected() {
        let mut b = sample_log(&meta2(), true);
        b[4] = REC_VERSION as u8 + 1;
        let err = decode_recording(&b, true).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn garbage_after_trailer_rejected() {
        let mut b = sample_log(&meta2(), true);
        b.push(0xff);
        let err = decode_recording(&b, true).unwrap_err().to_string();
        assert!(err.contains("after the trailer"), "{err}");
    }

    #[test]
    fn sink_writes_decodable_log_and_trailer() {
        let dir = std::env::temp_dir()
            .join(format!("vhrec-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sink = RecorderSink::create(&dir, &meta2()).unwrap();
        sink.log_frame(Dir::GuestToDevice, 0, 0, b"abc");
        sink.log_frame(Dir::DeviceToGuest, 1, 1, b"defg");
        assert_eq!(sink.frames(), 2);
        let path = sink
            .finish(&[
                DeviceFinal { cycles: 10, records_done: 1 },
                DeviceFinal { cycles: 20, records_done: 2 },
            ])
            .unwrap();
        let rec = read_recording(&path, false).unwrap();
        assert_eq!(rec.events.len(), 2);
        assert_eq!(rec.trailer.unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dropped_sink_flushes_partial_log() {
        let dir = std::env::temp_dir()
            .join(format!("vhrec-drop-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let sink = RecorderSink::create(&dir, &meta2()).unwrap();
            sink.log_frame(Dir::GuestToDevice, 0, 0, b"orphan");
            // No finish(): simulate a run that died.
        }
        let rec = read_recording(&dir, true).unwrap();
        assert!(rec.partial);
        assert_eq!(rec.events.len(), 1);
        assert_eq!(rec.events[0].bytes, b"orphan");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recording_transport_taps_both_directions() {
        let dir = std::env::temp_dir()
            .join(format!("vhrec-tap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sink = RecorderSink::create(&dir, &meta2()).unwrap();
        let (tx_end, mut peer) = make_inproc_pair();
        let mut tap =
            RecordingTransport::new(Box::new(tx_end), sink.clone(), 1, 0);
        tap.send(b"out-frame").unwrap();
        peer.send(b"in-frame").unwrap();
        assert_eq!(tap.try_recv().unwrap().unwrap(), b"in-frame");
        let path = sink.finish(&[DeviceFinal::default(); 2]).unwrap();
        let rec = read_recording(&path, false).unwrap();
        assert_eq!(rec.events.len(), 2);
        assert_eq!(rec.events[0].dir, Dir::DeviceToGuest);
        assert_eq!(rec.events[0].bytes, b"out-frame");
        assert_eq!(rec.events[1].dir, Dir::GuestToDevice);
        assert_eq!(rec.events[1].bytes, b"in-frame");
        assert_eq!(rec.events[1].device, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
