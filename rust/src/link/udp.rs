//! UDP loopback transport: real sockets, real loss.
//!
//! The step from "co-sim on one host over perfect pipes" toward a
//! simulated datacenter fabric (ROADMAP's renet direction): frames ride
//! UDP datagrams, which the kernel may drop, and which the reliable
//! channel layer above must survive. One [`UdpTransport`] is one
//! unidirectional channel, matching the paper's four-channel topology;
//! datagrams are naturally framed, so no length prefix is needed.
//!
//! Datagram layout (little-endian):
//! `session u64 | tseq u64 | frame bytes`
//!
//! * `session` — the sender incarnation's stamp. The receiver adopts
//!   the first stamp it sees; a *changed* stamp means the peer
//!   restarted, and is surfaced through `take_reconnected` so the
//!   reliable layer re-handshakes and replays — the same semantics a
//!   UDS re-accept provides.
//! * `tseq` — per-transport datagram counter. Used only to *observe*
//!   network reordering in stats; ordering and dedup are the reliable
//!   layer's job (frames carry their own stream seq).
//!
//! Everything here is wall-clock-free: no deadlines, no naps — the
//! blocking-wait seams live in the channel layer and the trait's
//! default `recv_timeout`, both already sanctioned in
//! `analysis/allow.toml`.

use std::io::ErrorKind;
use std::net::UdpSocket;

use super::transport::Transport;
use crate::{Error, Result};

/// Datagram header: session stamp + transport sequence.
const HDR: usize = 16;

/// Largest frame accepted for a single datagram (safely under the
/// 65,507-byte UDP payload ceiling; link frames are ≤ a few KiB).
pub const MAX_UDP_FRAME: usize = 60_000;

/// One unidirectional UDP channel end (loopback-first: both ends bind
/// 127.0.0.1). Build senders with [`UdpTransport::sender`] and
/// receivers with [`UdpTransport::receiver`].
pub struct UdpTransport {
    sock: UdpSocket,
    /// Incarnation stamp on outgoing datagrams (sender role).
    session: u64,
    /// Outgoing datagram counter.
    tx_seq: u64,
    /// Adopted peer stamp (receiver role); 0 = nothing received yet.
    peer_session: u64,
    /// Highest transport seq seen from the current peer incarnation.
    last_tseq: u64,
    newly_connected: bool,
    /// One datagram pulled ahead by `ready` and served by the next
    /// receive call.
    pending: Option<Vec<u8>>,
    rdbuf: Vec<u8>,
    wrbuf: Vec<u8>,
    /// Sends the kernel refused (peer port unbound, buffer full, …) —
    /// loss, by this transport's contract, never an error.
    pub send_lost: u64,
    /// Datagrams too short to carry the header.
    pub runts: u64,
    /// Peer session stamp changes after the first adoption.
    pub session_flips: u64,
    /// Datagrams that arrived behind an already-seen transport seq.
    pub reorder_observed: u64,
}

impl UdpTransport {
    fn new(sock: UdpSocket, session: u64) -> Result<Self> {
        sock.set_nonblocking(true)?;
        Ok(Self {
            sock,
            session,
            tx_seq: 0,
            peer_session: 0,
            last_tseq: 0,
            newly_connected: false,
            pending: None,
            rdbuf: vec![0u8; 64 * 1024],
            wrbuf: Vec::with_capacity(256),
            send_lost: 0,
            runts: 0,
            session_flips: 0,
            reorder_observed: 0,
        })
    }

    /// Sending end: bind an ephemeral loopback port and direct all
    /// datagrams at `peer_port`. `session` must be fresh per
    /// incarnation (see `coordinator::lifecycle::fresh_session`).
    pub fn sender(peer_port: u16, session: u64) -> Result<Self> {
        let sock = UdpSocket::bind(("127.0.0.1", 0))?;
        sock.connect(("127.0.0.1", peer_port))?;
        Self::new(sock, session)
    }

    /// Receiving end: bind `port` on loopback (0 = OS-assigned; read it
    /// back with [`UdpTransport::local_port`]).
    pub fn receiver(port: u16) -> Result<Self> {
        Self::new(UdpSocket::bind(("127.0.0.1", port))?, 0)
    }

    /// The locally bound port (the rendezvous coordinate peers send to).
    pub fn local_port(&self) -> Result<u16> {
        Ok(self.sock.local_addr()?.port())
    }

    /// Pull one datagram off the socket, strip and validate the header.
    fn recv_raw(&mut self) -> Result<Option<Vec<u8>>> {
        loop {
            match self.sock.recv_from(&mut self.rdbuf) {
                Ok((n, _from)) => {
                    if n < HDR || n > self.rdbuf.len() {
                        self.runts += 1;
                        continue;
                    }
                    let (Some(s8), Some(t8)) =
                        (self.rdbuf.get(..8), self.rdbuf.get(8..HDR))
                    else {
                        self.runts += 1;
                        continue;
                    };
                    let mut w = [0u8; 8];
                    w.copy_from_slice(s8);
                    let sess = u64::from_le_bytes(w);
                    w.copy_from_slice(t8);
                    let tseq = u64::from_le_bytes(w);
                    if sess != self.peer_session {
                        // First datagram, or a restarted peer: either
                        // way a fresh stream for the reliable layer.
                        if self.peer_session != 0 {
                            self.session_flips += 1;
                        }
                        self.peer_session = sess;
                        self.newly_connected = true;
                        self.last_tseq = 0;
                    }
                    if tseq <= self.last_tseq && self.last_tseq != 0 {
                        self.reorder_observed += 1;
                    } else {
                        self.last_tseq = tseq;
                    }
                    let body = self
                        .rdbuf
                        .get(HDR..n)
                        .ok_or_else(|| Error::link("udp recv overran its buffer"))?;
                    return Ok(Some(body.to_vec()));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(None),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                // ICMP port-unreachable residue from a connected
                // socket's earlier sends surfaces here; it means "peer
                // not up yet", which on a lossy link is not an error.
                Err(e)
                    if e.kind() == ErrorKind::ConnectionRefused
                        || e.kind() == ErrorKind::ConnectionReset =>
                {
                    continue
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

impl Transport for UdpTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if frame.len() > MAX_UDP_FRAME {
            return Err(Error::link(format!(
                "frame of {} bytes exceeds the {MAX_UDP_FRAME}-byte udp cap",
                frame.len()
            )));
        }
        self.tx_seq += 1;
        let mut buf = std::mem::take(&mut self.wrbuf);
        buf.clear();
        buf.extend_from_slice(&self.session.to_le_bytes());
        buf.extend_from_slice(&self.tx_seq.to_le_bytes());
        buf.extend_from_slice(frame);
        // A refused/overflowing send is loss, not failure: the frame
        // stays in the reliable layer's outbox and retransmit heals it
        // (this is what rides out the peer-process startup race).
        if self.sock.send(&buf).is_err() {
            self.send_lost += 1;
        }
        self.wrbuf = buf;
        Ok(())
    }

    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        if let Some(f) = self.pending.take() {
            return Ok(Some(f));
        }
        self.recv_raw()
    }

    fn ready(&mut self) -> Result<bool> {
        if self.pending.is_some() {
            return Ok(true);
        }
        self.pending = self.recv_raw()?;
        Ok(self.pending.is_some())
    }

    fn peek_reconnected(&self) -> bool {
        self.newly_connected
    }

    fn take_reconnected(&mut self) -> bool {
        std::mem::take(&mut self.newly_connected)
    }

    fn lossy(&self) -> bool {
        true
    }

    fn label(&self) -> &'static str {
        "udp"
    }
}

/// Port of channel `chan` (0–3: a_req, a_resp, b_req, b_resp) for
/// device `device` on base port `base` — the fixed rendezvous scheme
/// split VM/HDL processes agree on (`--udp-port`).
pub fn device_port(base: u16, device: u8, chan: u8) -> Result<u16> {
    let off = device as u32 * 4 + chan as u32;
    u16::try_from(base as u32 + off).map_err(|_| {
        Error::config(format!(
            "udp port overflow: base {base} + device {device} channel {chan}"
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Collision-free pair: receiver binds an OS-assigned port.
    fn pair(session: u64) -> (UdpTransport, UdpTransport) {
        let rx = UdpTransport::receiver(0).unwrap();
        let tx = UdpTransport::sender(rx.local_port().unwrap(), session).unwrap();
        (tx, rx)
    }

    #[test]
    fn loopback_roundtrip_preserves_frames() {
        let (mut tx, mut rx) = pair(7);
        tx.send(b"hello").unwrap();
        tx.send(&vec![9u8; 4096]).unwrap();
        let f1 = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(f1, b"hello");
        let f2 = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(f2.len(), 4096);
        assert!(f2.iter().all(|&b| b == 9));
        assert!(rx.try_recv().unwrap().is_none());
        assert!(tx.lossy() && rx.lossy());
    }

    #[test]
    fn first_datagram_marks_fresh_stream() {
        let (mut tx, mut rx) = pair(42);
        assert!(!rx.peek_reconnected());
        tx.send(b"x").unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert!(rx.peek_reconnected());
        assert!(rx.take_reconnected());
        assert!(!rx.take_reconnected(), "flag must be consumed once");
    }

    #[test]
    fn session_change_resurfaces_fresh_stream() {
        let rx0 = UdpTransport::receiver(0).unwrap();
        let port = rx0.local_port().unwrap();
        let mut rx = rx0;
        let mut tx1 = UdpTransport::sender(port, 100).unwrap();
        tx1.send(b"a").unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert!(rx.take_reconnected());
        // Same incarnation: no flip.
        tx1.send(b"b").unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert!(!rx.take_reconnected());
        // Restarted peer (new session stamp): fresh stream again.
        let mut tx2 = UdpTransport::sender(port, 101).unwrap();
        tx2.send(b"c").unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert!(rx.take_reconnected());
        assert_eq!(rx.session_flips, 1);
    }

    #[test]
    fn send_to_unbound_port_is_counted_loss_not_error() {
        // Bind-then-drop to get a port that is almost surely unbound.
        let port = {
            let probe = UdpTransport::receiver(0).unwrap();
            probe.local_port().unwrap()
        };
        let mut tx = UdpTransport::sender(port, 1).unwrap();
        for _ in 0..4 {
            tx.send(b"into the void").unwrap();
        }
        // At least some sends bounce once the ICMP unreachable lands;
        // either way none of them may error.
        let _ = tx.send_lost;
    }

    #[test]
    fn oversize_frame_is_rejected_runts_are_dropped() {
        let (mut tx, mut rx) = pair(1);
        assert!(tx.send(&vec![0u8; MAX_UDP_FRAME + 1]).is_err());
        // A headerless datagram straight on the socket is dropped.
        let raw = UdpSocket::bind(("127.0.0.1", 0)).unwrap();
        raw.send_to(b"runt", ("127.0.0.1", rx.local_port().unwrap())).unwrap();
        tx.send(b"real").unwrap();
        let f = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(f, b"real");
        assert_eq!(rx.runts, 1);
    }

    #[test]
    fn device_port_scheme_is_disjoint_and_bounded() {
        let mut seen = std::collections::BTreeSet::new();
        for dev in 0..4u8 {
            for chan in 0..4u8 {
                assert!(seen.insert(device_port(40_000, dev, chan).unwrap()));
            }
        }
        assert!(device_port(u16::MAX - 2, 200, 3).is_err());
    }

    #[test]
    fn ready_prefetch_does_not_lose_frames() {
        let (mut tx, mut rx) = pair(5);
        tx.send(b"one").unwrap();
        // Give loopback a moment, then ready() must prefetch.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !rx.ready().unwrap() {
            assert!(std::time::Instant::now() < deadline, "datagram never arrived");
            std::thread::sleep(Duration::from_micros(100));
        }
        assert_eq!(rx.try_recv().unwrap().unwrap(), b"one");
    }
}
