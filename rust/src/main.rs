//! `vmhdl` — command-line front end of the co-simulation framework.
//!
//! ```text
//! vmhdl cosim     [--records N] [--mode mmio|tlp] [--transport inproc|uds|udp]
//!                 [--devices N] [--shard round-robin|size|work-steal]
//!                 [--queue-depth D] [--device-latency k=cycles[,..]]
//!                 [--kernel sort|checksum|stats | --kernel k=kind[,..]]
//!                 [--device-n k=N] [--device-link-latency k=us]
//!                 [--impair drop=P,dup=P,reorder=P,corrupt=P,jitter=US,seed=N[,dir=up|down]]
//!                 [--device-impair k:spec] [--udp-port BASE]
//!                 [--fault k=class@rec=N[,class@rec=M,..]]  inject deterministic
//!                 PCIe faults on device k, each firing once at its own
//!                 non-posted index (classes: completion-timeout,
//!                 surprise-down, poisoned-cpl, ur-status, reset-inflight,
//!                 credit-starve) — the run reports per-record outcomes and a
//!                 fleet health summary instead of failing
//!                 [--lane-threads T]  worker threads servicing the HDL device
//!                 lanes (0 = auto: min(devices, cores); T = 1 keeps the
//!                 single-threaded merged-horizon loop; per-device cycle
//!                 counts are identical for any T — only wall clock changes)
//!                 [--vcd out.vcd] [--golden true] ...   run a full co-simulation
//!                 (devices > 1 shards the batch across N PCIe FPGAs;
//!                 queue-depth > 1 pipelines D records per device over
//!                 a scatter-gather descriptor ring; per-device --kernel
//!                 / --device-n runs a heterogeneous mixed fleet with
//!                 records routed to matching-kernel devices; --transport
//!                 udp crosses real loopback datagrams and --impair adds
//!                 seeded deterministic drop/dup/reorder/corrupt faults
//!                 that the reliability layer must absorb)
//! vmhdl hdl-side  --dir <sockets> [...]    the HDL simulator process
//!                 (UDS, or --transport udp --udp-port BASE)
//! vmhdl vm-side   [--dir <sockets>] [...]  the VM process (UDS or udp)
//! vmhdl replay    <dir> [--checkpoint K]   VM-less replay of a recorded run
//!                 (record one with `cosim --record <dir>`; replay feeds the
//!                 logged guest→device frames back into fresh platform lanes
//!                 and asserts the device→guest byte stream and per-device
//!                 final cycle counts match the log exactly; --checkpoint K
//!                 forks the run through a snapshot/restore round-trip after
//!                 K injected frames)
//! vmhdl rtt       [--iters N]              MMIO round-trip microbench (Table III)
//! vmhdl irq       [--iters N]              interrupt-latency microbench
//! vmhdl golden    [--records N] [--backend native|pjrt]
//!                                          run the golden model directly (func mode)
//! vmhdl flow      [--records N]            Table II debug-iteration comparison
//! vmhdl resources                          §III resource-utilization model
//! vmhdl topology                           print the component graph (Figure 1)
//! ```
//!
//! Every subcommand accepts `--config file.conf` (`key = value` lines)
//! plus the flags in `config.rs`.

use std::sync::atomic::{AtomicBool, AtomicU64};
use std::sync::Arc;
use std::time::Duration;

use vmhdl::config::Config;
use vmhdl::coordinator::cosim::{run_hdl_multi_loop, TransportKind};
use vmhdl::coordinator::stats::fmt_dur;
use vmhdl::coordinator::scenario;
use vmhdl::costmodel::{flow, FlowModel, ResourceModel};
use vmhdl::hdl::platform::Platform;
use vmhdl::link::{Endpoint, Side};
use vmhdl::runtime::{self, GoldenBackend};
use vmhdl::testutil::XorShift64;
use vmhdl::Result;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("vmhdl: error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    if cmd == "replay" {
        // Positional <dir> before the flag pairs — handled before the
        // generic `--key value` parser.
        return cmd_replay(&args[1..]);
    }
    let mut cfg = Config::default();
    cfg.apply_args(&args[1..])?;
    match cmd.as_str() {
        "cosim" => cmd_cosim(&cfg),
        "hdl-side" => cmd_hdl_side(&cfg),
        "vm-side" => cmd_vm_side(&cfg),
        "rtt" => cmd_rtt(&cfg),
        "irq" => cmd_irq(&cfg),
        "golden" => cmd_golden(&cfg),
        "flow" => cmd_flow(&cfg),
        "resources" => {
            print!("{}", ResourceModel::paper_platform().render());
            Ok(())
        }
        "topology" => {
            print!("{}", topology());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(vmhdl::Error::config(format!("unknown command {other:?}")))
        }
    }
}

fn print_usage() {
    println!(
        "vmhdl — VM-HDL co-simulation framework (paper reproduction)\n\
         commands: cosim, replay, hdl-side, vm-side, rtt, irq, golden, flow, \
         resources, topology\n\
         options:  --config file.conf plus the keys in rust/src/config.rs\n\
         replay:   vmhdl replay <dir> [--checkpoint K] — offline replay of a \
         `cosim --record <dir>` recording, no VM required"
    );
}

fn cmd_replay(args: &[String]) -> Result<()> {
    let usage = "usage: vmhdl replay <dir> [--checkpoint K]";
    let Some(dir) = args.first().filter(|a| !a.starts_with("--")) else {
        return Err(vmhdl::Error::config(usage));
    };
    let mut checkpoint: Option<usize> = None;
    let rest = &args[1..];
    let mut i = 0;
    while i < rest.len() {
        match rest[i].as_str() {
            "--checkpoint" => {
                let v = rest
                    .get(i + 1)
                    .ok_or_else(|| vmhdl::Error::config("--checkpoint needs a value"))?;
                checkpoint = Some(
                    v.parse()
                        .map_err(|_| vmhdl::Error::config(format!("bad --checkpoint: {v:?}")))?,
                );
                i += 2;
            }
            other => {
                return Err(vmhdl::Error::config(format!(
                    "replay: unknown flag {other:?} ({usage})"
                )))
            }
        }
    }
    let rep = vmhdl::coordinator::replay::replay_dir(std::path::Path::new(dir), checkpoint)?;
    println!(
        "replay: {} devices, {} recorded events — {} frames injected, {} device→guest \
         frames byte-checked{}{}",
        rep.devices,
        rep.events,
        rep.injected,
        rep.compared,
        if rep.checkpoint_forked { ", forked through a snapshot checkpoint" } else { "" },
        if rep.partial { " (partial crash log: trailer checks skipped)" } else { "" },
    );
    for (k, (cycles, records)) in rep
        .per_device_cycles
        .iter()
        .zip(rep.per_device_records.iter())
        .enumerate()
    {
        println!("  dev{k}: {cycles} cycles, {records} records — matches the recording");
    }
    Ok(())
}

fn cmd_cosim(cfg: &Config) -> Result<()> {
    println!(
        "co-simulation: {} records, mode={:?}, transport={}, devices={}, \
         lane-threads={}, golden={}{}",
        cfg.records,
        cfg.mode,
        cfg.transport,
        cfg.devices,
        vmhdl::coordinator::lanepool::effective_lane_threads(cfg.lane_threads, cfg.devices),
        cfg.golden,
        if cfg.golden { format!(" (backend {})", cfg.backend) } else { String::new() }
    );
    let mut golden: Option<Box<dyn GoldenBackend>> = if cfg.golden {
        Some(runtime::load_backend(cfg.backend, &cfg.artifacts, cfg.n)?)
    } else {
        None
    };
    if cfg.needs_sharded_runner() {
        return cmd_cosim_sharded(cfg, golden.as_deref_mut());
    }
    let rep =
        scenario::run_sort_offload(cfg.cosim()?, cfg.records, cfg.seed, golden.as_deref_mut())?;
    println!(
        "offload: {} records in {} wall / {} device-cycles ({} device time)",
        rep.records,
        fmt_dur(rep.wall),
        rep.device_cycles,
        fmt_dur(Duration::from_nanos(vmhdl::hdl::cycles_to_ns(rep.device_cycles)))
    );
    // Honest rate: fast-forwarded cycles cost no wall time, so they
    // are excluded — this is ticked cycles per second of busy wall.
    let ticked = rep.hdl.cycles.saturating_sub(rep.hdl.fast_forwarded_cycles);
    println!(
        "hdl side: {} cycles ({} ticked) in {} busy / {} idle ({:.2} Mcycles/s ticked), \
         {} mmio reads, {} mmio writes, {} dma reads, {} dma writes, {} irqs",
        rep.hdl.cycles,
        ticked,
        fmt_dur(rep.hdl.wall_busy),
        fmt_dur(rep.hdl.wall_idle),
        ticked as f64 / rep.hdl.wall_busy.as_secs_f64().max(1e-9) / 1e6,
        rep.hdl.mmio_reads,
        rep.hdl.mmio_writes,
        rep.hdl.dma_read_reqs,
        rep.hdl.dma_write_reqs,
        rep.hdl.irqs_sent,
    );
    println!(
        "scheduler: {} cycles fast-forwarded, {} idle waits ({} wakeups)",
        rep.hdl.fast_forwarded_cycles, rep.hdl.idle_waits, rep.hdl.wakeups,
    );
    println!(
        "link: {} messages, {} bytes{}",
        rep.link_msgs,
        rep.link_bytes,
        if rep.golden_checked { " — results golden-checked against the reference model" } else { "" }
    );
    if !cfg.device_fault.is_empty() {
        print_fault_outcomes(&rep.outcomes, &rep.health());
    }
    Ok(())
}

/// Per-record outcome listing + fleet health, printed whenever a
/// `--fault` plan was armed (the run completes and reports instead of
/// failing on the injected fault).
fn print_fault_outcomes(
    outcomes: &[scenario::RecordOutcome],
    health: &scenario::FleetHealth,
) {
    for (i, o) in outcomes.iter().enumerate() {
        if *o != scenario::RecordOutcome::Ok {
            println!("  record {i}: {o}");
        }
    }
    println!("fleet health: {health}");
}

/// Multi-device / pipelined / mixed-fleet cosim: shard the batch,
/// then report aggregate and per-device figures.
fn cmd_cosim_sharded(cfg: &Config, golden: Option<&mut dyn GoldenBackend>) -> Result<()> {
    let cc = cfg.cosim()?;
    let specs = scenario::device_specs(&cc);
    let (rep, _outs) = scenario::run_sharded_offload_depth(
        cc,
        cfg.records,
        cfg.seed,
        cfg.shard,
        cfg.queue_depth,
        golden,
    )?;
    println!(
        "sharded offload: {} records over {} devices ({} policy, depth {}) in {} wall \
         ({:.1} records/s aggregate)",
        rep.records,
        rep.devices,
        rep.policy,
        rep.queue_depth,
        fmt_dur(rep.wall),
        rep.records as f64 / rep.wall.as_secs_f64().max(1e-9),
    );
    for (k, hdl) in rep.hdl.iter().enumerate() {
        let ticked = hdl.cycles.saturating_sub(hdl.fast_forwarded_cycles);
        println!(
            "  dev{k} [{} n={}]: {} records, {} device-cycles ({} ticked, {} fast-forwarded), \
             {} busy / {} idle, {} irqs, {} desc fetches",
            specs[k].kernel,
            specs[k].n,
            rep.per_device_records[k],
            rep.per_device_cycles[k],
            ticked,
            hdl.fast_forwarded_cycles,
            fmt_dur(hdl.wall_busy),
            fmt_dur(hdl.wall_idle),
            hdl.irqs_sent,
            hdl.desc_fetches,
        );
    }
    println!(
        "link: {} messages, {} bytes over {} channel sets{}",
        rep.link_msgs,
        rep.link_bytes,
        rep.devices,
        if rep.golden_checked { " — results golden-checked" } else { "" }
    );
    if !cfg.device_fault.is_empty() {
        print_fault_outcomes(&rep.outcomes, &rep.health());
    }
    Ok(())
}

fn cmd_hdl_side(cfg: &Config) -> Result<()> {
    let cc = cfg.cosim()?;
    let session = vmhdl::coordinator::lifecycle::fresh_session();
    let n = cfg.devices.max(1);
    let udp = cfg.transport == "udp";
    if udp {
        println!(
            "hdl-side: udp base port {}, devices {n}, session {session:#x}, vcd={:?}",
            cfg.udp_port, cfg.vcd
        );
    } else {
        println!(
            "hdl-side: sockets at {}, devices {n}, session {session:#x}, vcd={:?}",
            cfg.socket_dir.display(),
            cfg.vcd
        );
    }
    // One lane per device on the selected transport (UDS devices
    // rendezvous under per-device socket subdirectories, dev0 = the
    // base dir; UDP devices bind the fixed device_port scheme). Runs
    // until killed (the supervisor / user stops us).
    let mut lanes = Vec::with_capacity(n);
    for k in 0..n {
        let mut ep = if udp {
            Endpoint::udp(Side::Hdl, cfg.udp_port, k as u8, session)?
        } else {
            let devdir = Endpoint::uds_device_dir(&cfg.socket_dir, k as u8);
            std::fs::create_dir_all(&devdir)?;
            let mut ep = Endpoint::uds(Side::Hdl, &devdir, session)?;
            ep.set_device_id(k as u8);
            ep
        };
        ep.set_send_latency(vmhdl::coordinator::cosim::link_latency_for(&cc, k));
        if let Some(ic) = vmhdl::coordinator::cosim::impair_for(&cc, k) {
            ep.impair(&ic);
        }
        lanes.push((
            Platform::new(vmhdl::coordinator::cosim::platform_cfg_for(&cc, k)),
            ep,
        ));
    }
    let stop = Arc::new(AtomicBool::new(false));
    let cycles: Vec<_> = (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();
    let reports = run_hdl_multi_loop(lanes, &cc, stop, cycles)?;
    for (k, report) in reports.iter().enumerate() {
        println!("hdl-side: dev{k} done: {report:?}");
    }
    Ok(())
}

fn cmd_vm_side(cfg: &Config) -> Result<()> {
    let mut c2 = cfg.clone();
    // A vm-side process is by definition split from the HDL side:
    // inproc makes no sense here. An explicit udp selection is kept;
    // anything else becomes uds.
    if c2.transport != "udp" {
        c2.transport = "uds".to_string();
    }
    let mut cc = c2.cosim()?;
    if let TransportKind::Udp { hdl_in_proc, .. } = &mut cc.transport {
        // The HDL side is the peer `vmhdl hdl-side` process.
        *hdl_in_proc = false;
    }
    if cfg.needs_sharded_runner() {
        let (rep, _outs) = scenario::run_sharded_offload_depth(
            cc,
            cfg.records,
            cfg.seed,
            cfg.shard,
            cfg.queue_depth,
            None,
        )?;
        println!(
            "vm-side: {} records ok over {} devices in {} (per-device cycles {:?})",
            rep.records,
            rep.devices,
            fmt_dur(rep.wall),
            rep.per_device_cycles
        );
        return Ok(());
    }
    let rep = scenario::run_sort_offload(cc, cfg.records, cfg.seed, None)?;
    println!(
        "vm-side: {} records ok in {} ({} device cycles)",
        rep.records,
        fmt_dur(rep.wall),
        rep.device_cycles
    );
    Ok(())
}

fn cmd_rtt(cfg: &Config) -> Result<()> {
    let (gap, rep) = scenario::run_rtt(cfg.cosim()?, cfg.iters)?;
    println!("MMIO read RTT over {} iterations:", rep.iters);
    println!("  wall: min={} avg={}", fmt_dur(rep.wall_min), fmt_dur(rep.wall_avg));
    println!(
        "  device: {} cycles/op ({} simulated-device time)",
        rep.device_cycles / rep.iters.max(1) as u64,
        fmt_dur(gap.actual)
    );
    println!("  gap factor (wall / device-time): {:.0}x", gap.factor());
    Ok(())
}

fn cmd_irq(cfg: &Config) -> Result<()> {
    let h = scenario::run_irq_latency(cfg.cosim()?, cfg.iters)?;
    println!("IRQ doorbell→ISR latency: {}", h.summary());
    Ok(())
}

fn cmd_golden(cfg: &Config) -> Result<()> {
    let mut g = runtime::load_backend(cfg.backend, &cfg.artifacts, cfg.n)?;
    let mut rng = XorShift64::new(cfg.seed);
    let records: Vec<Vec<i32>> = (0..cfg.records).map(|_| rng.vec_i32(cfg.n)).collect();
    let t0 = std::time::Instant::now();
    let out = g.func_offload(&records, false)?;
    let wall = t0.elapsed();
    for (o, i) in out.iter().zip(&records) {
        let mut e = i.clone();
        e.sort_unstable();
        assert_eq!(o, &e, "golden result mismatch");
    }
    println!(
        "functional mode ({} backend, no HDL): {} records in {} ({} per record; prep {} once)",
        g.name(),
        cfg.records,
        fmt_dur(wall),
        fmt_dur(wall / cfg.records.max(1) as u32),
        fmt_dur(g.stats().compile_wall),
    );
    Ok(())
}

fn cmd_flow(cfg: &Config) -> Result<()> {
    // Co-sim column measured live; physical column from the model.
    let model = FlowModel::paper();
    let resources = ResourceModel::paper_platform();
    let luts = resources.platform().luts;

    // "Compilation" (VCS analogue): incremental rebuild of the
    // simulator — measured if VMHDL_MEASURE_REBUILD=1, else the
    // recorded calibration (see EXPERIMENTS.md).
    let compile = measure_or_recorded_rebuild();
    let t0 = std::time::Instant::now();
    let rep = scenario::run_sort_offload(cfg.cosim()?, cfg.records, cfg.seed, None)?;
    let exec = t0.elapsed();
    let phys = model.physical_iteration(
        luts,
        Duration::from_nanos(vmhdl::hdl::cycles_to_ns(rep.device_cycles)),
    );
    let cosim = FlowModel::cosim_iteration(compile, exec);
    print!("{}", flow::render_table2(&phys, &cosim));
    Ok(())
}

/// See EXPERIMENTS.md §T2 — the recorded incremental-rebuild time of
/// the simulator after touching one HDL module (the VCS-compile
/// analogue), measured on this container. Set VMHDL_MEASURE_REBUILD=1
/// to re-measure live (slow: runs cargo).
fn measure_or_recorded_rebuild() -> Duration {
    if std::env::var("VMHDL_MEASURE_REBUILD").as_deref() == Ok("1") {
        let t0 = std::time::Instant::now();
        let status = std::process::Command::new("cargo")
            .args(["build", "--release", "--offline"])
            .env("CARGO_TARGET_DIR", "/tmp/vmhdl-rebuild-target")
            .status();
        if status.map(|s| s.success()).unwrap_or(false) {
            return t0.elapsed();
        }
    }
    Duration::from_secs_f64(crate::RECORDED_REBUILD_SECS)
}

/// Calibrated on this container (see EXPERIMENTS.md §T2).
const RECORDED_REBUILD_SECS: f64 = 40.0;

fn topology() -> String {
    // Figure 1, as the live component graph.
    "VM-HDL CO-SIMULATION TOPOLOGY (paper Figure 1)\n\
     \n\
     ┌─ VM side ──────────────────────────┐      ┌─ HDL side ─────────────────────────┐\n\
     │ guest app (sort workload)          │      │ FPGA platform @ 250 MHz            │\n\
     │   └─ sort driver (kernel module)   │      │   AXI interconnect                 │\n\
     │        │ MMIO / IRQ / DMA buffers  │      │   ├─ 0x0000   regfile (CSR)        │\n\
     │ VMM                                │      │   ├─ 0x1000   AXI DMA (MM2S/S2MM)  │\n\
     │   ├─ guest memory (DMA target)     │      │   └─ 0x100000 BRAM (BAR2)          │\n\
     │   └─ PCIe FPGA pseudo device       │      │   DMA ⇄ kernel: AXI-Stream 128b    │\n\
     │        BAR0 64K, BAR2 1M, MSI×4    │      │   stream kernel (probed via CSR):  │\n\
     │        │                           │      │   sort 1024×32b in 1256 cycles |   │\n\
     │        │                           │      │   checksum | stats                 │\n\
     │        │                           │      │   PCIe simulation bridge           │\n\
     └────────┼───────────────────────────┘      └────────┬───────────────────────────┘\n\
     \n\
              │   pair A: req →  (MMIO read/write)        │\n\
              │           ← resp (read completions)       │\n\
              └───────────────────────────────────────────┘\n\
                  pair B: req ←  (DMA read/write, MSI)\n\
                          → resp (DMA read completions)\n\
     \n\
     channels: reliable seq-numbered queues (ZeroMQ substitute);\n\
     either side may restart independently — the survivor replays.\n"
        .to_string()
}
