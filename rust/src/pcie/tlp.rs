//! PCIe Transaction Layer Packet codec — the **vpcie baseline** (§V).
//!
//! The paper contrasts its high-level MMIO/interrupt messages with
//! vpcie, which "forwards low-level PCIe messages that require extra
//! software to process". To reproduce that comparison we implement the
//! TLP subset a memory-mapped endpoint uses — MRd32/64, MWr32/64 and
//! CplD — with real 3/4-DW headers (big-endian header words, DW
//! granularity, first/last byte enables), and a link mode where the
//! pseudo device and the bridge exchange raw TLP bytes instead of
//! high-level messages. MSI in TLP mode is what it is on real PCIe: a
//! MemWr to the MSI address window.
//!
//! Restrictions (documented, matching what the baseline needs):
//! addresses and lengths are DW-aligned; a TLP carries ≤ 1024 DW.

use crate::{Error, Result};

/// TLP format/type fields we implement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tlp {
    /// Memory read request.
    MemRd {
        addr: u64,
        /// Length in DW (1..=1024).
        len_dw: u16,
        tag: u8,
        requester: u16,
    },
    /// Memory write request (posted) with payload.
    MemWr { addr: u64, data: Vec<u8>, requester: u16 },
    /// Completion with data.
    CplD {
        tag: u8,
        completer: u16,
        requester: u16,
        data: Vec<u8>,
        /// Completion status (0 = SC).
        status: u8,
    },
}

/// The MSI doorbell window on x86 (FEEx_xxxx): a MemWr here is an MSI.
pub const MSI_WINDOW_BASE: u64 = 0xFEE0_0000;
pub const MSI_WINDOW_SIZE: u64 = 0x0010_0000;

/// True if a write to `addr` is an MSI doorbell.
pub fn is_msi_address(addr: u64) -> bool {
    (MSI_WINDOW_BASE..MSI_WINDOW_BASE + MSI_WINDOW_SIZE).contains(&addr)
}

const FMT_3DW_NODATA: u8 = 0b000;
const FMT_4DW_NODATA: u8 = 0b001;
const FMT_3DW_DATA: u8 = 0b010;
const FMT_4DW_DATA: u8 = 0b011;
const TYPE_MEM: u8 = 0b0_0000;
const TYPE_CPL: u8 = 0b0_1010;

fn be32(v: u32) -> [u8; 4] {
    v.to_be_bytes()
}
fn rd_be32(b: &[u8]) -> u32 {
    u32::from_be_bytes(b.try_into().unwrap())
}

impl Tlp {
    /// Encode to wire bytes (header DWs big-endian + payload).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Tlp::MemRd { addr, len_dw, tag, requester } => {
                assert!((1..=1024).contains(len_dw), "MRd len {len_dw}");
                assert!(addr % 4 == 0, "MRd addr unaligned");
                let four_dw = *addr > u32::MAX as u64;
                let fmt = if four_dw { FMT_4DW_NODATA } else { FMT_3DW_NODATA };
                let mut v = Vec::with_capacity(16);
                let len_field = if *len_dw == 1024 { 0 } else { *len_dw as u32 };
                v.extend_from_slice(&be32(
                    ((fmt as u32) << 29) | ((TYPE_MEM as u32) << 24) | len_field,
                ));
                // Byte enables: full DWs (0xF first/last).
                v.extend_from_slice(&be32(
                    ((*requester as u32) << 16) | ((*tag as u32) << 8) | 0xFF,
                ));
                if four_dw {
                    v.extend_from_slice(&be32((*addr >> 32) as u32));
                }
                v.extend_from_slice(&be32(*addr as u32 & !0x3));
                v
            }
            Tlp::MemWr { addr, data, requester } => {
                assert!(addr % 4 == 0 && data.len() % 4 == 0, "MWr unaligned");
                let len_dw = data.len() / 4;
                assert!((1..=1024).contains(&len_dw), "MWr len {len_dw}");
                let four_dw = *addr > u32::MAX as u64;
                let fmt = if four_dw { FMT_4DW_DATA } else { FMT_3DW_DATA };
                let mut v = Vec::with_capacity(16 + data.len());
                let len_field = if len_dw == 1024 { 0 } else { len_dw as u32 };
                v.extend_from_slice(&be32(
                    ((fmt as u32) << 29) | ((TYPE_MEM as u32) << 24) | len_field,
                ));
                v.extend_from_slice(&be32(((*requester as u32) << 16) | 0xFF));
                if four_dw {
                    v.extend_from_slice(&be32((*addr >> 32) as u32));
                }
                v.extend_from_slice(&be32(*addr as u32 & !0x3));
                v.extend_from_slice(data);
                v
            }
            Tlp::CplD { tag, completer, requester, data, status } => {
                assert!(data.len() % 4 == 0, "CplD unaligned payload");
                let len_dw = data.len() / 4;
                assert!((1..=1024).contains(&len_dw), "CplD len {len_dw}");
                let mut v = Vec::with_capacity(16 + data.len());
                let len_field = if len_dw == 1024 { 0 } else { len_dw as u32 };
                v.extend_from_slice(&be32(
                    ((FMT_3DW_DATA as u32) << 29) | ((TYPE_CPL as u32) << 24) | len_field,
                ));
                let byte_count = data.len() as u32 & 0xFFF;
                v.extend_from_slice(&be32(
                    ((*completer as u32) << 16) | (((*status as u32) & 0x7) << 13) | byte_count,
                ));
                v.extend_from_slice(&be32(((*requester as u32) << 16) | ((*tag as u32) << 8)));
                v.extend_from_slice(data);
                v
            }
        }
    }

    /// Decode wire bytes.
    pub fn decode(b: &[u8]) -> Result<Tlp> {
        if b.len() < 12 || b.len() % 4 != 0 {
            return Err(Error::pcie(format!("TLP too short/unaligned: {}", b.len())));
        }
        let dw0 = rd_be32(&b[0..4]);
        let fmt = ((dw0 >> 29) & 0x7) as u8;
        let typ = ((dw0 >> 24) & 0x1F) as u8;
        let len_field = dw0 & 0x3FF;
        let len_dw = if len_field == 0 { 1024 } else { len_field as usize };
        let has_data = fmt == FMT_3DW_DATA || fmt == FMT_4DW_DATA;
        let four_dw = fmt == FMT_4DW_NODATA || fmt == FMT_4DW_DATA;
        let hdr_dw = if four_dw { 4 } else { 3 };
        let expect = hdr_dw * 4 + if has_data { len_dw * 4 } else { 0 };
        if b.len() != expect {
            return Err(Error::pcie(format!(
                "TLP length mismatch: have {}, header says {expect}",
                b.len()
            )));
        }
        match (typ, has_data) {
            (TYPE_MEM, false) => {
                let dw1 = rd_be32(&b[4..8]);
                let addr = if four_dw {
                    ((rd_be32(&b[8..12]) as u64) << 32) | rd_be32(&b[12..16]) as u64
                } else {
                    rd_be32(&b[8..12]) as u64
                };
                Ok(Tlp::MemRd {
                    addr: addr & !0x3,
                    len_dw: len_dw as u16,
                    tag: (dw1 >> 8) as u8,
                    requester: (dw1 >> 16) as u16,
                })
            }
            (TYPE_MEM, true) => {
                let dw1 = rd_be32(&b[4..8]);
                let (addr, data_off) = if four_dw {
                    (
                        ((rd_be32(&b[8..12]) as u64) << 32) | rd_be32(&b[12..16]) as u64,
                        16,
                    )
                } else {
                    (rd_be32(&b[8..12]) as u64, 12)
                };
                Ok(Tlp::MemWr {
                    addr: addr & !0x3,
                    data: b[data_off..].to_vec(),
                    requester: (dw1 >> 16) as u16,
                })
            }
            (TYPE_CPL, true) => {
                let dw1 = rd_be32(&b[4..8]);
                let dw2 = rd_be32(&b[8..12]);
                Ok(Tlp::CplD {
                    tag: (dw2 >> 8) as u8,
                    completer: (dw1 >> 16) as u16,
                    requester: (dw2 >> 16) as u16,
                    data: b[12..].to_vec(),
                    status: ((dw1 >> 13) & 0x7) as u8,
                })
            }
            other => Err(Error::pcie(format!("unsupported TLP type {other:?}"))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Tlp::MemRd { .. } => "MRd",
            Tlp::MemWr { .. } => "MWr",
            Tlp::CplD { .. } => "CplD",
        }
    }
}

/// Split a byte-length memory read into ≤4 KiB TLP reads (max payload
/// rules), returning `(addr, len_dw)` pieces. Models the extra
/// fragmentation work the low-level baseline must do.
pub fn fragment_read(addr: u64, len: u32, max_payload_dw: u16) -> Vec<(u64, u16)> {
    assert!(addr % 4 == 0 && len % 4 == 0);
    let mut out = Vec::new();
    let mut a = addr;
    let mut remaining_dw = (len / 4) as u32;
    while remaining_dw > 0 {
        let take = remaining_dw.min(max_payload_dw as u32) as u16;
        out.push((a, take));
        a += take as u64 * 4;
        remaining_dw -= take as u32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn roundtrip_mrd_32_and_64() {
        for addr in [0x1000u64, 0x2_0000_0000] {
            let t = Tlp::MemRd { addr, len_dw: 16, tag: 7, requester: 0x0100 };
            let back = Tlp::decode(&t.encode()).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn roundtrip_mwr_and_cpld() {
        let t = Tlp::MemWr {
            addr: 0x8000_0000,
            data: vec![1, 2, 3, 4, 5, 6, 7, 8],
            requester: 0x0200,
        };
        assert_eq!(Tlp::decode(&t.encode()).unwrap(), t);
        let c = Tlp::CplD {
            tag: 9,
            completer: 0x0100,
            requester: 0x0200,
            data: vec![0xAA; 64],
            status: 0,
        };
        assert_eq!(Tlp::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn len_1024_dw_encodes_as_zero() {
        let t = Tlp::MemRd { addr: 0, len_dw: 1024, tag: 0, requester: 0 };
        let enc = t.encode();
        assert_eq!(rd_be32(&enc[0..4]) & 0x3FF, 0);
        assert_eq!(Tlp::decode(&enc).unwrap(), t);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Tlp::decode(&[]).is_err());
        assert!(Tlp::decode(&[0; 8]).is_err());
        let t = Tlp::MemWr { addr: 0, data: vec![0; 8], requester: 0 };
        let mut enc = t.encode();
        enc.truncate(enc.len() - 4); // payload shorter than header len
        assert!(Tlp::decode(&enc).is_err());
    }

    #[test]
    fn msi_window() {
        assert!(is_msi_address(0xFEE0_0000));
        assert!(is_msi_address(0xFEEF_FFFC));
        assert!(!is_msi_address(0xFED0_0000));
    }

    #[test]
    fn fragment_read_covers_exactly() {
        let pieces = fragment_read(0x1000, 4096 + 512, 256);
        let total: u32 = pieces.iter().map(|&(_, dw)| dw as u32 * 4).sum();
        assert_eq!(total, 4096 + 512);
        assert_eq!(pieces[0], (0x1000, 256));
        // Contiguity.
        for w in pieces.windows(2) {
            assert_eq!(w[0].0 + w[0].1 as u64 * 4, w[1].0);
        }
    }

    #[test]
    fn prop_roundtrip_random_tlps() {
        forall(
            0x71F0,
            300,
            |g| {
                let kind = g.rng.range(0, 2);
                let ndw = g.size(256);
                let data = g.rng.vec_u8(ndw * 4);
                let addr = (g.rng.next_u64() >> g.rng.range(0, 32)) & !0x3;
                match kind {
                    0 => Tlp::MemRd {
                        addr,
                        len_dw: ndw as u16,
                        tag: g.rng.next_u32() as u8,
                        requester: g.rng.next_u32() as u16,
                    },
                    1 => Tlp::MemWr { addr, data, requester: g.rng.next_u32() as u16 },
                    _ => Tlp::CplD {
                        tag: g.rng.next_u32() as u8,
                        completer: g.rng.next_u32() as u16,
                        requester: g.rng.next_u32() as u16,
                        data,
                        status: (g.rng.next_u32() % 8) as u8,
                    },
                }
            },
            |t| {
                let back = Tlp::decode(&t.encode()).map_err(|e| e.to_string())?;
                if &back != t {
                    return Err(format!("roundtrip mangled: {back:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_fragment_never_exceeds_max_payload() {
        forall(
            0xF4A6,
            200,
            |g| {
                let len = (g.size(64 * 1024) as u32 + 3) & !3;
                let max = [16u16, 32, 64, 128, 256][g.rng.range(0, 4)];
                (g.rng.below(1 << 40) & !0x3, len.max(4), max)
            },
            |&(addr, len, max)| {
                let pieces = fragment_read(addr, len, max);
                let total: u32 = pieces.iter().map(|&(_, dw)| dw as u32 * 4).sum();
                if total != len {
                    return Err(format!("covered {total} of {len}"));
                }
                if pieces.iter().any(|&(_, dw)| dw > max || dw == 0) {
                    return Err("piece size out of range".into());
                }
                Ok(())
            },
        );
    }
}
