//! PCIe Transaction Layer Packet codec.
//!
//! Originally the **vpcie baseline** (§V) — the paper contrasts its
//! high-level MMIO/interrupt messages with vpcie, which "forwards
//! low-level PCIe messages that require extra software to process".
//! Since the TLP-fidelity data path landed, this codec is also the
//! *main* transport in `LinkMode::Tlp`: device DMA reads/writes and
//! MSI all travel as encoded TLPs, with max-payload fragmentation
//! ([`fragment_read`]), tag matching and completion status codes.
//!
//! We implement the TLP subset a memory-mapped endpoint uses —
//! MRd32/64, MWr32/64, CplD and data-less Cpl (error completions) —
//! with real 3/4-DW headers (big-endian header words, DW granularity,
//! first/last byte enables), the EP (poisoned data) bit, and the
//! SC/UR/CA completion status field.
//!
//! Restrictions (documented, matching what the endpoint needs):
//! addresses and lengths are DW-aligned; a TLP carries ≤ 1024 DW.
//! This file is in the `cargo xtask analyze` panic-audit scope: the
//! codec is fed by a peer process over a socket, so malformed or
//! oversized input must surface as `Error::pcie`, never a panic —
//! construction goes through the `Result`-returning constructors
//! ([`Tlp::mem_rd`], [`Tlp::mem_wr`], [`Tlp::cpl_d`]) and
//! [`Tlp::encode`] re-validates before emitting bytes.

use crate::{Error, Result};

/// Completion status: Successful Completion.
pub const STATUS_SC: u8 = 0b000;
/// Completion status: Unsupported Request.
pub const STATUS_UR: u8 = 0b001;
/// Completion status: Completer Abort.
pub const STATUS_CA: u8 = 0b100;

/// Human-readable completion status (for fault triage messages).
pub fn status_name(status: u8) -> &'static str {
    match status {
        STATUS_SC => "SC",
        STATUS_UR => "UR",
        STATUS_CA => "CA",
        _ => "reserved",
    }
}

/// TLP format/type fields we implement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tlp {
    /// Memory read request.
    MemRd {
        addr: u64,
        /// Length in DW (1..=1024).
        len_dw: u16,
        tag: u8,
        requester: u16,
    },
    /// Memory write request (posted) with payload.
    MemWr { addr: u64, data: Vec<u8>, requester: u16 },
    /// Completion, with data for SC and an empty payload for error
    /// statuses (UR/CA travel as a data-less Cpl on the wire).
    CplD {
        tag: u8,
        completer: u16,
        requester: u16,
        data: Vec<u8>,
        /// Completion status ([`STATUS_SC`] / [`STATUS_UR`] /
        /// [`STATUS_CA`]).
        status: u8,
        /// EP bit (header DW0 bit 14): payload delivered but known
        /// corrupt — the receiver must not consume it as good data.
        poisoned: bool,
    },
}

/// The MSI doorbell window on x86 (FEEx_xxxx): a MemWr here is an MSI.
pub const MSI_WINDOW_BASE: u64 = 0xFEE0_0000;
pub const MSI_WINDOW_SIZE: u64 = 0x0010_0000;

/// True if a write to `addr` is an MSI doorbell.
pub fn is_msi_address(addr: u64) -> bool {
    (MSI_WINDOW_BASE..MSI_WINDOW_BASE + MSI_WINDOW_SIZE).contains(&addr)
}

const FMT_3DW_NODATA: u8 = 0b000;
const FMT_4DW_NODATA: u8 = 0b001;
const FMT_3DW_DATA: u8 = 0b010;
const FMT_4DW_DATA: u8 = 0b011;
const TYPE_MEM: u8 = 0b0_0000;
const TYPE_CPL: u8 = 0b0_1010;
/// EP ("poisoned data") bit in header DW0.
const DW0_EP: u32 = 1 << 14;

/// 3-DW header size in bytes (MRd32/MWr32/Cpl*).
pub const HDR_3DW_BYTES: u32 = 12;
/// 4-DW header size in bytes (MRd64/MWr64).
pub const HDR_4DW_BYTES: u32 = 16;

fn be32(v: u32) -> [u8; 4] {
    v.to_be_bytes()
}

/// Big-endian u32 from the first 4 bytes (0 on short input — callers
/// bounds-check first; this keeps the hot path free of panics).
fn rd_be32(b: &[u8]) -> u32 {
    match (b.first(), b.get(1), b.get(2), b.get(3)) {
        (Some(&a), Some(&x), Some(&y), Some(&z)) => u32::from_be_bytes([a, x, y, z]),
        _ => 0,
    }
}

fn check_len_dw(len_dw: usize, what: &str) -> Result<()> {
    if (1..=1024).contains(&len_dw) {
        Ok(())
    } else {
        Err(Error::pcie(format!("{what} length {len_dw} DW outside 1..=1024")))
    }
}

impl Tlp {
    /// Validated memory read request.
    pub fn mem_rd(addr: u64, len_dw: u16, tag: u8, requester: u16) -> Result<Tlp> {
        check_len_dw(len_dw as usize, "MRd")?;
        if addr % 4 != 0 {
            return Err(Error::pcie(format!("MRd addr {addr:#x} not DW-aligned")));
        }
        Ok(Tlp::MemRd { addr, len_dw, tag, requester })
    }

    /// Validated posted memory write.
    pub fn mem_wr(addr: u64, data: Vec<u8>, requester: u16) -> Result<Tlp> {
        if addr % 4 != 0 || data.len() % 4 != 0 {
            return Err(Error::pcie(format!(
                "MWr addr {addr:#x} / payload {}B not DW-aligned",
                data.len()
            )));
        }
        check_len_dw(data.len() / 4, "MWr")?;
        Ok(Tlp::MemWr { addr, data, requester })
    }

    /// Validated completion. Successful completions carry a DW-aligned
    /// payload; UR/CA completions must be data-less.
    pub fn cpl_d(
        tag: u8,
        completer: u16,
        requester: u16,
        data: Vec<u8>,
        status: u8,
        poisoned: bool,
    ) -> Result<Tlp> {
        if data.len() % 4 != 0 {
            return Err(Error::pcie(format!("CplD payload {}B not DW-aligned", data.len())));
        }
        if status == STATUS_SC {
            check_len_dw(data.len() / 4, "CplD")?;
        } else if !data.is_empty() {
            return Err(Error::pcie(format!(
                "{} completion must be data-less, got {}B",
                status_name(status),
                data.len()
            )));
        }
        Ok(Tlp::CplD { tag, completer, requester, data, status, poisoned })
    }

    /// Encode to wire bytes (header DWs big-endian + payload).
    /// Re-validates the same invariants as the constructors so a
    /// hand-built `Tlp` cannot emit a malformed frame.
    pub fn encode(&self) -> Result<Vec<u8>> {
        match self {
            Tlp::MemRd { addr, len_dw, tag, requester } => {
                check_len_dw(*len_dw as usize, "MRd")?;
                if addr % 4 != 0 {
                    return Err(Error::pcie(format!("MRd addr {addr:#x} not DW-aligned")));
                }
                let four_dw = *addr > u32::MAX as u64;
                let fmt = if four_dw { FMT_4DW_NODATA } else { FMT_3DW_NODATA };
                let mut v = Vec::with_capacity(16);
                let len_field = if *len_dw == 1024 { 0 } else { *len_dw as u32 };
                v.extend_from_slice(&be32(
                    ((fmt as u32) << 29) | ((TYPE_MEM as u32) << 24) | len_field,
                ));
                // Byte enables: full DWs (0xF first/last).
                v.extend_from_slice(&be32(
                    ((*requester as u32) << 16) | ((*tag as u32) << 8) | 0xFF,
                ));
                if four_dw {
                    v.extend_from_slice(&be32((*addr >> 32) as u32));
                }
                v.extend_from_slice(&be32(*addr as u32 & !0x3));
                Ok(v)
            }
            Tlp::MemWr { addr, data, requester } => {
                if addr % 4 != 0 || data.len() % 4 != 0 {
                    return Err(Error::pcie("MWr addr/payload not DW-aligned".into()));
                }
                let len_dw = data.len() / 4;
                check_len_dw(len_dw, "MWr")?;
                let four_dw = *addr > u32::MAX as u64;
                let fmt = if four_dw { FMT_4DW_DATA } else { FMT_3DW_DATA };
                let mut v = Vec::with_capacity(16 + data.len());
                let len_field = if len_dw == 1024 { 0 } else { len_dw as u32 };
                v.extend_from_slice(&be32(
                    ((fmt as u32) << 29) | ((TYPE_MEM as u32) << 24) | len_field,
                ));
                v.extend_from_slice(&be32(((*requester as u32) << 16) | 0xFF));
                if four_dw {
                    v.extend_from_slice(&be32((*addr >> 32) as u32));
                }
                v.extend_from_slice(&be32(*addr as u32 & !0x3));
                v.extend_from_slice(data);
                Ok(v)
            }
            Tlp::CplD { tag, completer, requester, data, status, poisoned } => {
                if data.len() % 4 != 0 {
                    return Err(Error::pcie("CplD payload not DW-aligned".into()));
                }
                let len_dw = data.len() / 4;
                let has_data = !data.is_empty();
                if has_data {
                    check_len_dw(len_dw, "CplD")?;
                } else if *status == STATUS_SC {
                    return Err(Error::pcie("SC completion without data".into()));
                }
                let fmt = if has_data { FMT_3DW_DATA } else { FMT_3DW_NODATA };
                let mut v = Vec::with_capacity(16 + data.len());
                let len_field = if len_dw == 1024 { 0 } else { len_dw as u32 };
                let ep = if *poisoned { DW0_EP } else { 0 };
                v.extend_from_slice(&be32(
                    ((fmt as u32) << 29) | ((TYPE_CPL as u32) << 24) | ep | len_field,
                ));
                let byte_count = data.len() as u32 & 0xFFF;
                v.extend_from_slice(&be32(
                    ((*completer as u32) << 16) | (((*status as u32) & 0x7) << 13) | byte_count,
                ));
                v.extend_from_slice(&be32(((*requester as u32) << 16) | ((*tag as u32) << 8)));
                v.extend_from_slice(data);
                Ok(v)
            }
        }
    }

    /// Decode wire bytes.
    pub fn decode(b: &[u8]) -> Result<Tlp> {
        if b.len() < 12 || b.len() % 4 != 0 {
            return Err(Error::pcie(format!("TLP too short/unaligned: {}", b.len())));
        }
        let dw0 = rd_be32(&b[0..4]);
        let fmt = ((dw0 >> 29) & 0x7) as u8;
        let typ = ((dw0 >> 24) & 0x1F) as u8;
        let poisoned = dw0 & DW0_EP != 0;
        let len_field = dw0 & 0x3FF;
        let len_dw = if len_field == 0 { 1024 } else { len_field as usize };
        let has_data = fmt == FMT_3DW_DATA || fmt == FMT_4DW_DATA;
        let four_dw = fmt == FMT_4DW_NODATA || fmt == FMT_4DW_DATA;
        let hdr_dw = if four_dw { 4 } else { 3 };
        let expect = hdr_dw * 4 + if has_data { len_dw * 4 } else { 0 };
        if b.len() != expect {
            return Err(Error::pcie(format!(
                "TLP length mismatch: have {}, header says {expect}",
                b.len()
            )));
        }
        match (typ, has_data) {
            (TYPE_MEM, false) => {
                let dw1 = rd_be32(&b[4..8]);
                let addr = if four_dw {
                    ((rd_be32(&b[8..12]) as u64) << 32) | rd_be32(&b[12..16]) as u64
                } else {
                    rd_be32(&b[8..12]) as u64
                };
                Ok(Tlp::MemRd {
                    addr: addr & !0x3,
                    len_dw: len_dw as u16,
                    tag: (dw1 >> 8) as u8,
                    requester: (dw1 >> 16) as u16,
                })
            }
            (TYPE_MEM, true) => {
                let dw1 = rd_be32(&b[4..8]);
                let (addr, data_off) = if four_dw {
                    (
                        ((rd_be32(&b[8..12]) as u64) << 32) | rd_be32(&b[12..16]) as u64,
                        16,
                    )
                } else {
                    (rd_be32(&b[8..12]) as u64, 12)
                };
                Ok(Tlp::MemWr {
                    addr: addr & !0x3,
                    data: b.get(data_off..).unwrap_or(&[]).to_vec(),
                    requester: (dw1 >> 16) as u16,
                })
            }
            (TYPE_CPL, data) => {
                let dw1 = rd_be32(&b[4..8]);
                let dw2 = rd_be32(&b[8..12]);
                Ok(Tlp::CplD {
                    tag: (dw2 >> 8) as u8,
                    completer: (dw1 >> 16) as u16,
                    requester: (dw2 >> 16) as u16,
                    data: if data { b.get(12..).unwrap_or(&[]).to_vec() } else { Vec::new() },
                    status: ((dw1 >> 13) & 0x7) as u8,
                    poisoned,
                })
            }
            other => Err(Error::pcie(format!("unsupported TLP type {other:?}"))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Tlp::MemRd { .. } => "MRd",
            Tlp::MemWr { .. } => "MWr",
            Tlp::CplD { data, .. } if data.is_empty() => "Cpl",
            Tlp::CplD { .. } => "CplD",
        }
    }

    /// Wire header size in bytes for this TLP (3 or 4 DW).
    pub fn header_bytes(&self) -> u32 {
        match self {
            Tlp::MemRd { addr, .. } | Tlp::MemWr { addr, .. } => {
                if *addr > u32::MAX as u64 {
                    HDR_4DW_BYTES
                } else {
                    HDR_3DW_BYTES
                }
            }
            Tlp::CplD { .. } => HDR_3DW_BYTES,
        }
    }
}

/// Split a byte-length memory read into max-payload-sized TLP reads,
/// returning `(addr, len_dw)` pieces. Live on the main data path in
/// `LinkMode::Tlp` (the bridge fragments every DMA burst) and used by
/// the costmodel to price per-TLP header overhead.
///
/// Panic-free by construction: a zero `max_payload_dw` is clamped to
/// 1, byte lengths round up to whole DWs, and a misaligned `addr` is
/// masked down (callers on the main path pre-validate alignment).
pub fn fragment_read(addr: u64, len: u32, max_payload_dw: u16) -> Vec<(u64, u16)> {
    let max_dw = max_payload_dw.max(1) as u32;
    let mut out = Vec::new();
    let mut a = addr & !0x3;
    let mut remaining_dw = len.div_ceil(4);
    while remaining_dw > 0 {
        let take = remaining_dw.min(max_dw) as u16;
        out.push((a, take));
        a += take as u64 * 4;
        remaining_dw -= take as u32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn roundtrip_mrd_32_and_64() {
        for addr in [0x1000u64, 0x2_0000_0000] {
            let t = Tlp::mem_rd(addr, 16, 7, 0x0100).unwrap();
            let back = Tlp::decode(&t.encode().unwrap()).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn roundtrip_mwr_and_cpld() {
        let t = Tlp::mem_wr(0x8000_0000, vec![1, 2, 3, 4, 5, 6, 7, 8], 0x0200).unwrap();
        assert_eq!(Tlp::decode(&t.encode().unwrap()).unwrap(), t);
        let c = Tlp::cpl_d(9, 0x0100, 0x0200, vec![0xAA; 64], STATUS_SC, false).unwrap();
        assert_eq!(Tlp::decode(&c.encode().unwrap()).unwrap(), c);
    }

    #[test]
    fn roundtrip_error_and_poisoned_completions() {
        // UR/CA travel data-less; the status and tag survive.
        for status in [STATUS_UR, STATUS_CA] {
            let c = Tlp::cpl_d(3, 0x0100, 0x0008, Vec::new(), status, false).unwrap();
            let enc = c.encode().unwrap();
            assert_eq!(enc.len(), 12, "error completion is a bare 3-DW header");
            assert_eq!(Tlp::decode(&enc).unwrap(), c);
        }
        // EP bit survives a round trip alongside real data.
        let p = Tlp::cpl_d(7, 0x0100, 0x0008, vec![0x55; 16], STATUS_SC, true).unwrap();
        assert_eq!(Tlp::decode(&p.encode().unwrap()).unwrap(), p);
    }

    #[test]
    fn constructors_reject_malformed() {
        assert!(Tlp::mem_rd(0x1001, 4, 0, 0).is_err(), "unaligned addr");
        assert!(Tlp::mem_rd(0x1000, 0, 0, 0).is_err(), "zero length");
        assert!(Tlp::mem_rd(0x1000, 1025, 0, 0).is_err(), "over max length");
        assert!(Tlp::mem_wr(0x1000, vec![0; 3], 0).is_err(), "odd payload");
        assert!(Tlp::mem_wr(0x1000, Vec::new(), 0).is_err(), "empty MWr");
        assert!(Tlp::cpl_d(0, 0, 0, Vec::new(), STATUS_SC, false).is_err(), "SC without data");
        assert!(
            Tlp::cpl_d(0, 0, 0, vec![0; 4], STATUS_UR, false).is_err(),
            "UR with data"
        );
        // encode() re-validates a hand-built value.
        let bad = Tlp::MemRd { addr: 0x1000, len_dw: 0, tag: 0, requester: 0 };
        assert!(bad.encode().is_err());
    }

    #[test]
    fn len_1024_dw_encodes_as_zero() {
        let t = Tlp::mem_rd(0, 1024, 0, 0).unwrap();
        let enc = t.encode().unwrap();
        assert_eq!(rd_be32(&enc[0..4]) & 0x3FF, 0);
        assert_eq!(Tlp::decode(&enc).unwrap(), t);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Tlp::decode(&[]).is_err());
        assert!(Tlp::decode(&[0; 8]).is_err());
        let t = Tlp::mem_wr(0, vec![0; 8], 0).unwrap();
        let mut enc = t.encode().unwrap();
        enc.truncate(enc.len() - 4); // payload shorter than header len
        assert!(Tlp::decode(&enc).is_err());
    }

    #[test]
    fn msi_window() {
        assert!(is_msi_address(0xFEE0_0000));
        assert!(is_msi_address(0xFEEF_FFFC));
        assert!(!is_msi_address(0xFED0_0000));
    }

    #[test]
    fn fragment_read_covers_exactly() {
        let pieces = fragment_read(0x1000, 4096 + 512, 256);
        let total: u32 = pieces.iter().map(|&(_, dw)| dw as u32 * 4).sum();
        assert_eq!(total, 4096 + 512);
        assert_eq!(pieces[0], (0x1000, 256));
        // Contiguity.
        for w in pieces.windows(2) {
            assert_eq!(w[0].0 + w[0].1 as u64 * 4, w[1].0);
        }
    }

    #[test]
    fn prop_roundtrip_random_tlps() {
        forall(
            0x71F0,
            300,
            |g| {
                let kind = g.rng.range(0, 2);
                let ndw = g.size(256);
                let data = g.rng.vec_u8(ndw * 4);
                let addr = (g.rng.next_u64() >> g.rng.range(0, 32)) & !0x3;
                match kind {
                    0 => Tlp::MemRd {
                        addr,
                        len_dw: ndw as u16,
                        tag: g.rng.next_u32() as u8,
                        requester: g.rng.next_u32() as u16,
                    },
                    1 => Tlp::MemWr { addr, data, requester: g.rng.next_u32() as u16 },
                    _ => Tlp::CplD {
                        tag: g.rng.next_u32() as u8,
                        completer: g.rng.next_u32() as u16,
                        requester: g.rng.next_u32() as u16,
                        data,
                        status: STATUS_SC,
                        poisoned: g.rng.next_u32() & 1 != 0,
                    },
                }
            },
            |t| {
                let back =
                    Tlp::decode(&t.encode().map_err(|e| e.to_string())?).map_err(|e| e.to_string())?;
                if &back != t {
                    return Err(format!("roundtrip mangled: {back:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_fragment_never_exceeds_max_payload() {
        forall(
            0xF4A6,
            200,
            |g| {
                let len = (g.size(64 * 1024) as u32 + 3) & !3;
                let max = [16u16, 32, 64, 128, 256][g.rng.range(0, 4)];
                (g.rng.below(1 << 40) & !0x3, len.max(4), max)
            },
            |&(addr, len, max)| {
                let pieces = fragment_read(addr, len, max);
                let total: u32 = pieces.iter().map(|&(_, dw)| dw as u32 * 4).sum();
                if total != len {
                    return Err(format!("covered {total} of {len}"));
                }
                if pieces.iter().any(|&(_, dw)| dw > max || dw == 0) {
                    return Err("piece size out of range".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_decode_never_panics_on_mutation() {
        // Codec fuzz: truncate/extend/flip valid frames — decode must
        // return (Ok or structured Err), never panic.
        use crate::testutil::ByteMutator;
        forall(
            0xB00F,
            400,
            |g| {
                let mut base = Tlp::mem_wr(
                    (g.rng.below(1 << 40)) & !0x3,
                    g.rng.vec_u8(g.size(64) * 4),
                    g.rng.next_u32() as u16,
                )
                .and_then(|t| t.encode())
                .unwrap_or_default();
                let mut m = ByteMutator::new(g.rng.next_u64());
                m.mutate(&mut base);
                base
            },
            |bytes| {
                let _ = Tlp::decode(bytes);
                Ok(())
            },
        );
    }
}
