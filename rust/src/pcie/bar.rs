//! Base Address Register definitions and address decode.

use crate::{Error, Result};

/// BAR memory kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BarKind {
    /// 32-bit memory BAR, non-prefetchable.
    Mem32,
    /// 64-bit memory BAR (occupies two BAR slots).
    Mem64,
}

/// One BAR's static definition.
#[derive(Debug, Clone, Copy)]
pub struct BarDef {
    /// BAR slot index (0..6).
    pub index: u8,
    /// Size in bytes; must be a power of two ≥ 16.
    pub size: u64,
    pub kind: BarKind,
}

impl BarDef {
    pub fn new(index: u8, size: u64, kind: BarKind) -> Self {
        assert!(size.is_power_of_two() && size >= 16, "bad BAR size {size}");
        assert!(index < 6);
        Self { index, size, kind }
    }

    /// Low-bits type encoding as read from the BAR register.
    pub fn type_bits(&self) -> u32 {
        match self.kind {
            BarKind::Mem32 => 0b000,
            BarKind::Mem64 => 0b100,
        }
    }

    /// The sizing mask: writing all-ones returns this plus type bits.
    pub fn size_mask(&self) -> u64 {
        !(self.size - 1)
    }
}

/// The set of BARs of a device plus their guest-assigned bases.
#[derive(Debug, Clone)]
pub struct BarSet {
    defs: Vec<BarDef>,
    bases: Vec<u64>,
}

impl BarSet {
    pub fn new(defs: Vec<BarDef>) -> Self {
        let n = defs.len();
        Self {
            defs,
            bases: vec![0; n],
        }
    }

    pub fn defs(&self) -> &[BarDef] {
        &self.defs
    }

    pub fn def_by_index(&self, index: u8) -> Option<&BarDef> {
        self.defs.iter().find(|d| d.index == index)
    }

    /// Guest (or firmware) assigns a base address to a BAR.
    pub fn set_base(&mut self, index: u8, base: u64) -> Result<()> {
        let pos = self
            .defs
            .iter()
            .position(|d| d.index == index)
            .ok_or_else(|| Error::pcie(format!("no BAR {index}")))?;
        let def = &self.defs[pos];
        if base & (def.size - 1) != 0 {
            return Err(Error::pcie(format!(
                "BAR{index} base {base:#x} not aligned to size {:#x}",
                def.size
            )));
        }
        self.bases[pos] = base;
        Ok(())
    }

    pub fn base(&self, index: u8) -> Option<u64> {
        self.defs
            .iter()
            .position(|d| d.index == index)
            .map(|p| self.bases[p])
    }

    /// Decode a guest physical address into `(bar_index, offset)`.
    pub fn decode(&self, gpa: u64) -> Option<(u8, u64)> {
        for (d, &base) in self.defs.iter().zip(&self.bases) {
            if base != 0 && gpa >= base && gpa < base + d.size {
                return Some((d.index, gpa - base));
            }
        }
        None
    }

    /// Check that an access stays inside the BAR.
    pub fn check_access(&self, bar: u8, offset: u64, len: u64) -> Result<()> {
        let def = self
            .def_by_index(bar)
            .ok_or_else(|| Error::pcie(format!("access to undefined BAR {bar}")))?;
        if offset.checked_add(len).map_or(true, |end| end > def.size) {
            return Err(Error::pcie(format!(
                "access [{offset:#x}..+{len}) outside BAR{bar} (size {:#x})",
                def.size
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn sume_bars() -> BarSet {
        BarSet::new(vec![
            BarDef::new(0, 64 * 1024, BarKind::Mem32),
            BarDef::new(2, 1024 * 1024, BarKind::Mem64),
        ])
    }

    #[test]
    fn sizing_mask() {
        let d = BarDef::new(0, 64 * 1024, BarKind::Mem32);
        assert_eq!(d.size_mask() as u32, 0xFFFF_0000);
    }

    #[test]
    fn decode_routes_to_correct_bar() {
        let mut b = sume_bars();
        b.set_base(0, 0xF000_0000).unwrap();
        b.set_base(2, 0xF010_0000).unwrap();
        assert_eq!(b.decode(0xF000_0004), Some((0, 4)));
        assert_eq!(b.decode(0xF010_FFFF), Some((2, 0xFFFF)));
        assert_eq!(b.decode(0xF020_0000), None);
        assert_eq!(b.decode(0), None);
    }

    #[test]
    fn unaligned_base_rejected() {
        let mut b = sume_bars();
        assert!(b.set_base(0, 0xF000_1000).is_err());
    }

    #[test]
    fn check_access_bounds() {
        let b = sume_bars();
        assert!(b.check_access(0, 0, 4).is_ok());
        assert!(b.check_access(0, 64 * 1024 - 4, 4).is_ok());
        assert!(b.check_access(0, 64 * 1024 - 3, 4).is_err());
        assert!(b.check_access(0, u64::MAX, 4).is_err());
        assert!(b.check_access(1, 0, 4).is_err());
    }

    #[test]
    fn prop_decode_inverse_of_base_plus_offset() {
        forall(
            0xBA5E,
            200,
            |g| {
                let bar = if g.rng.chance(1, 2) { 0u8 } else { 2u8 };
                let off = g.rng.below(if bar == 0 { 64 * 1024 } else { 1024 * 1024 });
                (bar, off)
            },
            |&(bar, off)| {
                let mut b = sume_bars();
                b.set_base(0, 0xE000_0000).unwrap();
                b.set_base(2, 0xE100_0000).unwrap();
                let base = b.base(bar).unwrap();
                match b.decode(base + off) {
                    Some((dbar, doff)) if dbar == bar && doff == off => Ok(()),
                    other => Err(format!("decode({base:#x}+{off:#x}) = {other:?}")),
                }
            },
        );
    }
}
