//! The **PCIe FPGA pseudo device** — the VMM-side half of the link.
//!
//! Paper §II: *"We created a PCIe FPGA pseudo device in the VMM to
//! represent the PCIe FPGA board ... customizing it with the target
//! FPGA board's PCIe characteristics, such as the number and size of
//! the BAR regions and MSI capabilities. ... MMIO read and write
//! requests to the BAR regions are handled using callback functions
//! and translated into messages that are sent to the HDL simulator.
//! The PCIe FPGA pseudo device also configures the VMM to listen to
//! memory accesses and interrupts from the HDL side."*
//!
//! Two link modes:
//! * [`LinkMode::Mmio`] — the paper's high-level messages.
//! * [`LinkMode::Tlp`] — the vpcie baseline: every access is
//!   fragmented into raw PCIe TLPs which the other side must parse
//!   (more messages, more bytes, more work — quantified in §V benches).

use std::time::Duration;

use super::config_space::ConfigSpace;
use super::fault::{FaultAction, FaultPlan, FaultState};
use super::tlp::{self, Tlp};
use crate::link::{Endpoint, LinkMode, Msg};
use crate::{Error, Result};

/// Guest memory as seen by device DMA (implemented by `vm::mem::GuestMem`).
pub trait DmaTarget {
    fn dma_read(&self, addr: u64, len: u32) -> Result<Vec<u8>>;
    fn dma_write(&mut self, addr: u64, data: &[u8]) -> Result<()>;
}

/// Interrupt delivery into the guest (implemented by the VMM).
pub trait IrqSink {
    fn raise(&mut self, vector: u16);
}

/// Counters exposed for tests, metrics and the §V comparison.
#[derive(Debug, Default, Clone)]
pub struct PseudoDeviceStats {
    pub mmio_reads: u64,
    pub mmio_writes: u64,
    pub dma_reads: u64,
    pub dma_writes: u64,
    pub dma_bytes_read: u64,
    pub dma_bytes_written: u64,
    pub interrupts: u64,
    pub interrupts_dropped: u64,
    pub mmio_timeouts: u64,
    pub tlps_sent: u64,
    pub tlps_received: u64,
}

/// The pseudo device: config space + link endpoint + DMA/IRQ plumbing.
pub struct PcieFpgaDevice {
    pub config: ConfigSpace,
    link: Endpoint,
    mode: LinkMode,
    next_tag: u64,
    /// Max read-completion payload per TLP, in DW (TLP mode).
    max_payload_dw: u16,
    /// MMIO completion timeout — expiring means the "FPGA" hung,
    /// which is exactly the debugging scenario the framework exists for.
    pub mmio_timeout: Duration,
    pub stats: PseudoDeviceStats,
    /// Requester id used in TLPs — derived from the function's BDF at
    /// construction (multi-device topologies give every endpoint a
    /// distinct id, so completions route back unambiguously).
    requester_id: u16,
    /// Seeded fault-injection state (`--fault k=class@rec=N`).
    fault: FaultState,
}

impl PcieFpgaDevice {
    pub fn new(config: ConfigSpace, link: Endpoint, mode: LinkMode) -> Self {
        let bdf = config.bdf();
        // An unenumerated function (default 00:00.0) keeps the seed's
        // conventional 00:01.0 requester so TLP traffic never claims
        // the host bridge's id.
        let requester_id = if bdf == crate::pcie::Bdf::default() {
            crate::pcie::Bdf::new(0, 1, 0).requester_id()
        } else {
            bdf.requester_id()
        };
        Self {
            config,
            link,
            mode,
            next_tag: 1,
            max_payload_dw: 64, // 256B, a common MPS
            mmio_timeout: Duration::from_secs(10),
            stats: PseudoDeviceStats::default(),
            requester_id,
            fault: FaultState::default(),
        }
    }

    /// Arm (or clear) the deterministic fault plan for this device.
    pub fn set_fault(&mut self, plan: Option<FaultPlan>) {
        self.fault = FaultState::new(plan);
    }

    /// Arm a multi-plan fault list: each plan fires once, at its own
    /// non-posted index (see [`FaultState`]).
    pub fn set_faults(&mut self, plans: Vec<FaultPlan>) {
        self.fault = FaultState::new_multi(plans);
    }

    /// Fault-injection runtime state (plan, clock, firing record).
    pub fn fault_state(&self) -> &FaultState {
        &self.fault
    }

    /// This function's bus address (set by the enumerating VMM).
    pub fn bdf(&self) -> crate::pcie::Bdf {
        self.config.bdf()
    }

    pub fn mode(&self) -> LinkMode {
        self.mode
    }
    pub fn link(&self) -> &Endpoint {
        &self.link
    }
    pub fn link_mut(&mut self) -> &mut Endpoint {
        &mut self.link
    }

    fn take_tag(&mut self) -> u64 {
        let t = self.next_tag;
        self.next_tag += 1;
        t
    }

    /// Guest MMIO read of `len` bytes at `offset` within `bar`.
    /// Services interleaved HDL-side traffic (DMA/IRQ) while waiting
    /// for the completion, so the device can never deadlock against
    /// its own outstanding work.
    pub fn mmio_read(
        &mut self,
        bar: u8,
        offset: u64,
        len: u32,
        mem: &mut dyn DmaTarget,
        irq: &mut dyn IrqSink,
    ) -> Result<Vec<u8>> {
        self.config.bars().check_access(bar, offset, len as u64)?;
        if !self.config.mem_enabled() || self.fault.link_down() {
            // Reads while memory decoding is off — or after a
            // surprise link-down — return all-ones, as on real PCIe
            // (master abort). All-ones is exactly what the driver's
            // surprise-down detector keys on.
            return Ok(vec![0xFF; len as usize]);
        }
        self.stats.mmio_reads += 1;
        match self.mode {
            LinkMode::Mmio => {
                let tag = self.take_tag();
                self.link.send(&Msg::MmioRead { tag, bar, addr: offset, len })?;
                self.wait_completion(mem, irq, |m| match m {
                    Msg::MmioReadResp { tag: t, data } if *t == tag => Some(data.clone()),
                    _ => None,
                })
            }
            LinkMode::Tlp => {
                // The baseline cannot express "BAR-relative": it must
                // use bus addresses. BAR base + offset, DW-aligned.
                let base = self
                    .config
                    .bars()
                    .base(bar)
                    .ok_or_else(|| Error::pcie(format!("BAR{bar} unassigned")))?;
                let addr = base + offset;
                if addr % 4 != 0 || len % 4 != 0 {
                    return Err(Error::pcie("TLP mode requires DW-aligned MMIO"));
                }
                let mut out = Vec::with_capacity(len as usize);
                for (a, ndw) in tlp::fragment_read(addr, len, self.max_payload_dw) {
                    let tag = (self.take_tag() & 0xFF) as u8;
                    let t = Tlp::mem_rd(a, ndw, tag, self.requester_id)?;
                    self.stats.tlps_sent += 1;
                    self.link.send(&Msg::Tlp { bytes: t.encode()? })?;
                    let data = self.wait_completion(mem, irq, |m| match m {
                        Msg::Tlp { bytes } => match Tlp::decode(bytes) {
                            Ok(Tlp::CplD { tag: t2, data, .. }) if t2 == tag => Some(data),
                            _ => None,
                        },
                        _ => None,
                    })?;
                    out.extend_from_slice(&data);
                }
                Ok(out)
            }
        }
    }

    /// Guest MMIO write (posted).
    pub fn mmio_write(&mut self, bar: u8, offset: u64, data: &[u8]) -> Result<()> {
        self.config
            .bars()
            .check_access(bar, offset, data.len() as u64)?;
        if !self.config.mem_enabled() || self.fault.link_down() {
            return Ok(()); // dropped, as on real hardware
        }
        self.stats.mmio_writes += 1;
        match self.mode {
            LinkMode::Mmio => self.link.send(&Msg::MmioWrite {
                bar,
                addr: offset,
                data: data.to_vec(),
            }),
            LinkMode::Tlp => {
                let base = self
                    .config
                    .bars()
                    .base(bar)
                    .ok_or_else(|| Error::pcie(format!("BAR{bar} unassigned")))?;
                let addr = base + offset;
                if addr % 4 != 0 || data.len() % 4 != 0 {
                    return Err(Error::pcie("TLP mode requires DW-aligned MMIO"));
                }
                for chunk_start in (0..data.len()).step_by(self.max_payload_dw as usize * 4) {
                    let end = (chunk_start + self.max_payload_dw as usize * 4).min(data.len());
                    let t = Tlp::mem_wr(
                        addr + chunk_start as u64,
                        data[chunk_start..end].to_vec(),
                        self.requester_id,
                    )?;
                    self.stats.tlps_sent += 1;
                    self.link.send(&Msg::Tlp { bytes: t.encode()? })?;
                }
                Ok(())
            }
        }
    }

    /// Wait for a completion matching `extract`, servicing HDL-side
    /// requests that arrive in the meantime.
    fn wait_completion<T>(
        &mut self,
        mem: &mut dyn DmaTarget,
        irq: &mut dyn IrqSink,
        mut extract: impl FnMut(&Msg) -> Option<T>,
    ) -> Result<T> {
        // Poll-budget deadline instead of a wall-clock one: each
        // fruitless wait slice burns one unit of budget, and any link
        // traffic (the HDL side making observable progress) refills
        // it. The hang verdict therefore depends only on the message
        // stream, never on host scheduling jitter — the same
        // discipline as the PR 1 cycle-based driver hang detector —
        // while a detached/hung peer still times out after roughly
        // `mmio_timeout` of wall because each empty slice blocks for
        // `WAIT_SLICE` at most.
        const WAIT_SLICE: Duration = Duration::from_millis(5);
        let budget = (self.mmio_timeout.as_millis() / WAIT_SLICE.as_millis()).max(1) as u64;
        let mut empty_slices = 0u64;
        loop {
            // Process the WHOLE batch even after the completion is
            // found — HDL-side requests (DMA reads!) may share the
            // batch and must never be dropped.
            let mut found = None;
            let mut progressed = false;
            for m in self.link.poll()? {
                progressed = true;
                if found.is_none() {
                    if let Some(v) = extract(&m) {
                        found = Some(v);
                        continue;
                    }
                }
                self.service_msg(m, mem, irq)?;
            }
            if let Some(v) = found {
                return Ok(v);
            }
            if progressed {
                empty_slices = 0;
            } else if empty_slices >= budget {
                self.stats.mmio_timeouts += 1;
                return Err(Error::cosim(format!(
                    "MMIO completion timeout after {:?} — HDL side hung or detached",
                    self.mmio_timeout
                )));
            }
            // Block on the link doorbell instead of sleep-polling: an
            // in-proc completion wakes us the instant it is enqueued
            // (the RTT path of Table III), sockets nap-poll inside.
            self.link.wait_any(WAIT_SLICE)?;
            empty_slices += 1;
        }
    }

    /// One VMM main-loop iteration: drain the link, servicing HDL-side
    /// DMA reads/writes and interrupts (the "file descriptors
    /// registered with the VMM's main loop" of the paper).
    pub fn poll_service(
        &mut self,
        mem: &mut dyn DmaTarget,
        irq: &mut dyn IrqSink,
    ) -> Result<usize> {
        let msgs = self.link.poll()?;
        let n = msgs.len();
        for m in msgs {
            self.service_msg(m, mem, irq)?;
        }
        Ok(n)
    }

    /// Handle one HDL-initiated message.
    fn service_msg(
        &mut self,
        msg: Msg,
        mem: &mut dyn DmaTarget,
        irq: &mut dyn IrqSink,
    ) -> Result<()> {
        if self.fault.link_down() {
            // Surprise-down: the endpoint is gone. Everything the HDL
            // side sends from now on falls on the floor.
            return Ok(());
        }
        match msg {
            Msg::DmaRead { tag, addr, len } => {
                if !self.config.bus_master() {
                    // BME off: device DMA must be refused. Complete
                    // with an empty (aborted) response so the HDL side
                    // does not hang forever.
                    self.link.send(&Msg::DmaReadResp { tag, data: Vec::new() })?;
                    return Ok(());
                }
                match self.fault.on_nonposted(addr, len) {
                    Some(FaultAction::DropRequest) => return Ok(()),
                    // The high-level link has no EP bit or status
                    // field: poisoned and UR both degrade to an
                    // aborted (empty) response, which the bridge turns
                    // into SLVERR beats. TLP mode carries the full
                    // fidelity (see `service_tlp`).
                    Some(FaultAction::PoisonCompletion | FaultAction::UrCompletion) => {
                        self.link.send(&Msg::DmaReadResp { tag, data: Vec::new() })?;
                        return Ok(());
                    }
                    None => {}
                }
                self.stats.dma_reads += 1;
                self.stats.dma_bytes_read += len as u64;
                let data = mem.dma_read(addr, len)?;
                self.link.send(&Msg::DmaReadResp { tag, data })?;
            }
            Msg::DmaWrite { addr, data } => {
                if !self.config.bus_master() {
                    return Ok(()); // dropped
                }
                self.stats.dma_writes += 1;
                self.stats.dma_bytes_written += data.len() as u64;
                mem.dma_write(addr, &data)?;
            }
            Msg::Interrupt { vector } => self.deliver_msi(vector, irq),
            Msg::Tlp { bytes } => {
                self.stats.tlps_received += 1;
                let t = Tlp::decode(&bytes)?;
                self.service_tlp(t, mem, irq)?;
            }
            // Stale completions (e.g. a response to a request from a
            // previous incarnation after restart) are dropped.
            Msg::MmioReadResp { .. } | Msg::DmaReadResp { .. } => {}
            other => {
                return Err(Error::pcie(format!(
                    "unexpected message at pseudo device: {}",
                    other.label()
                )))
            }
        }
        Ok(())
    }

    /// vpcie-baseline servicing: raw TLPs from the HDL side.
    fn service_tlp(
        &mut self,
        t: Tlp,
        mem: &mut dyn DmaTarget,
        irq: &mut dyn IrqSink,
    ) -> Result<()> {
        match t {
            Tlp::MemRd { addr, len_dw, tag, requester } => {
                if !self.config.bus_master() {
                    return Ok(());
                }
                let len = len_dw as u32 * 4;
                let c = match self.fault.on_nonposted(addr, len) {
                    Some(FaultAction::DropRequest) => return Ok(()),
                    Some(FaultAction::PoisonCompletion) => {
                        // Real data, EP bit set: delivered but known
                        // corrupt. The bridge must not hand it to the
                        // DMA engine as good beats.
                        let data = mem.dma_read(addr, len)?;
                        Tlp::cpl_d(tag, 0x0000, requester, data, tlp::STATUS_SC, true)?
                    }
                    Some(FaultAction::UrCompletion) => {
                        Tlp::cpl_d(tag, 0x0000, requester, Vec::new(), tlp::STATUS_UR, false)?
                    }
                    None => {
                        self.stats.dma_reads += 1;
                        self.stats.dma_bytes_read += len as u64;
                        let data = mem.dma_read(addr, len)?;
                        Tlp::cpl_d(tag, 0x0000, requester, data, tlp::STATUS_SC, false)?
                    }
                };
                self.stats.tlps_sent += 1;
                self.link.send(&Msg::Tlp { bytes: c.encode()? })?;
            }
            Tlp::MemWr { addr, data, .. } => {
                if tlp::is_msi_address(addr) {
                    // An MSI is a posted write to the FEE window.
                    let vector = ((addr - tlp::MSI_WINDOW_BASE) / 4) as u16;
                    self.deliver_msi(vector, irq);
                } else {
                    if !self.config.bus_master() {
                        return Ok(());
                    }
                    self.stats.dma_writes += 1;
                    self.stats.dma_bytes_written += data.len() as u64;
                    mem.dma_write(addr, &data)?;
                }
            }
            Tlp::CplD { .. } => {} // stale completion
        }
        Ok(())
    }

    fn deliver_msi(&mut self, vector: u16, irq: &mut dyn IrqSink) {
        let msi = self.config.msi();
        if msi.enabled && vector < msi.vectors() {
            self.stats.interrupts += 1;
            irq.raise(vector);
        } else {
            // Masked or out-of-range: dropped, like real MSI.
            self.stats.interrupts_dropped += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcie::bar::{BarDef, BarKind, BarSet};
    use crate::pcie::board;
    use crate::pcie::config_space::{cmd, regs};

    struct TestMem(Vec<u8>);
    impl DmaTarget for TestMem {
        fn dma_read(&self, addr: u64, len: u32) -> Result<Vec<u8>> {
            Ok(self.0[addr as usize..(addr + len as u64) as usize].to_vec())
        }
        fn dma_write(&mut self, addr: u64, data: &[u8]) -> Result<()> {
            self.0[addr as usize..addr as usize + data.len()].copy_from_slice(data);
            Ok(())
        }
    }

    struct TestIrq(Vec<u16>);
    impl IrqSink for TestIrq {
        fn raise(&mut self, vector: u16) {
            self.0.push(vector);
        }
    }

    fn mkdev(mode: LinkMode) -> (PcieFpgaDevice, Endpoint) {
        let (vm_ep, hdl_ep) = Endpoint::inproc_pair();
        let cs = ConfigSpace::new(
            board::VENDOR_ID,
            board::DEVICE_ID,
            board::SUBSYS_ID,
            0x058000,
            BarSet::new(vec![
                BarDef::new(0, board::BAR0_SIZE, BarKind::Mem32),
                BarDef::new(2, board::BAR2_SIZE, BarKind::Mem64),
            ]),
            board::MSI_VECTORS,
        );
        let mut dev = PcieFpgaDevice::new(cs, vm_ep, mode);
        dev.mmio_timeout = Duration::from_millis(500);
        // Enable memory + bus mastering + MSI like a booted driver.
        dev.config
            .write32(regs::COMMAND, (cmd::MEM_ENABLE | cmd::BUS_MASTER) as u32)
            .unwrap();
        dev.config.write32(regs::MSI_CAP + 4, 0xFEE0_0000).unwrap();
        dev.config.write32(regs::MSI_CAP, (1 | (2 << 4)) << 16).unwrap();
        dev.config.bars_mut().set_base(0, 0xF000_0000).unwrap();
        dev.config.bars_mut().set_base(2, 0xF800_0000).unwrap();
        (dev, hdl_ep)
    }

    #[test]
    fn mmio_write_becomes_message() {
        let (mut dev, mut hdl) = mkdev(LinkMode::Mmio);
        dev.mmio_write(0, 0x10, &[1, 2, 3, 4]).unwrap();
        let got = hdl.poll().unwrap();
        assert_eq!(
            got,
            vec![Msg::MmioWrite { bar: 0, addr: 0x10, data: vec![1, 2, 3, 4] }]
        );
    }

    #[test]
    fn mmio_read_roundtrip_with_hdl_echo() {
        let (mut dev, mut hdl) = mkdev(LinkMode::Mmio);
        let h = std::thread::spawn(move || {
            // HDL side: answer the first read request with its addr.
            loop {
                for m in hdl.poll().unwrap() {
                    if let Msg::MmioRead { tag, addr, len, .. } = m {
                        let mut d = vec![0u8; len as usize];
                        d[..8.min(len as usize)]
                            .copy_from_slice(&addr.to_le_bytes()[..8.min(len as usize)]);
                        hdl.send(&Msg::MmioReadResp { tag, data: d }).unwrap();
                        return;
                    }
                }
                std::thread::sleep(Duration::from_micros(50));
            }
        });
        let mut mem = TestMem(vec![0; 64]);
        let mut irq = TestIrq(vec![]);
        let data = dev.mmio_read(0, 0x20, 4, &mut mem, &mut irq).unwrap();
        assert_eq!(data, vec![0x20, 0, 0, 0]);
        h.join().unwrap();
    }

    #[test]
    fn mmio_read_timeout_reports_hang() {
        let (mut dev, _hdl) = mkdev(LinkMode::Mmio);
        dev.mmio_timeout = Duration::from_millis(50);
        let mut mem = TestMem(vec![0; 8]);
        let mut irq = TestIrq(vec![]);
        let err = dev.mmio_read(0, 0, 4, &mut mem, &mut irq).unwrap_err();
        assert!(err.to_string().contains("timeout"), "{err}");
        assert_eq!(dev.stats.mmio_timeouts, 1);
    }

    #[test]
    fn mem_disabled_reads_all_ones() {
        let (mut dev, _hdl) = mkdev(LinkMode::Mmio);
        dev.config.write32(regs::COMMAND, 0).unwrap();
        let mut mem = TestMem(vec![0; 8]);
        let mut irq = TestIrq(vec![]);
        let d = dev.mmio_read(0, 0, 4, &mut mem, &mut irq).unwrap();
        assert_eq!(d, vec![0xFF; 4]);
    }

    #[test]
    fn services_dma_and_interrupts() {
        let (mut dev, mut hdl) = mkdev(LinkMode::Mmio);
        let mut mem = TestMem((0..64u8).collect());
        let mut irq = TestIrq(vec![]);
        hdl.send(&Msg::DmaRead { tag: 3, addr: 8, len: 8 }).unwrap();
        hdl.send(&Msg::DmaWrite { addr: 0, data: vec![0xAB; 4] }).unwrap();
        hdl.send(&Msg::Interrupt { vector: 1 }).unwrap();
        hdl.send(&Msg::Interrupt { vector: 77 }).unwrap(); // out of range
        dev.poll_service(&mut mem, &mut irq).unwrap();
        // DMA read answered:
        let resp = hdl.poll().unwrap();
        assert_eq!(
            resp,
            vec![Msg::DmaReadResp { tag: 3, data: (8..16u8).collect() }]
        );
        // DMA write landed:
        assert_eq!(&mem.0[..4], &[0xAB; 4]);
        // Valid interrupt delivered, invalid dropped:
        assert_eq!(irq.0, vec![1]);
        assert_eq!(dev.stats.interrupts_dropped, 1);
    }

    #[test]
    fn bus_master_off_blocks_dma() {
        let (mut dev, mut hdl) = mkdev(LinkMode::Mmio);
        dev.config.write32(regs::COMMAND, cmd::MEM_ENABLE as u32).unwrap();
        let mut mem = TestMem(vec![7; 64]);
        let mut irq = TestIrq(vec![]);
        hdl.send(&Msg::DmaRead { tag: 1, addr: 0, len: 8 }).unwrap();
        hdl.send(&Msg::DmaWrite { addr: 0, data: vec![0; 8] }).unwrap();
        dev.poll_service(&mut mem, &mut irq).unwrap();
        let resp = hdl.poll().unwrap();
        assert_eq!(resp, vec![Msg::DmaReadResp { tag: 1, data: vec![] }]);
        assert_eq!(mem.0[0], 7, "DMA write must be dropped with BME off");
    }

    #[test]
    fn tlp_mode_mmio_write_and_msi() {
        let (mut dev, mut hdl) = mkdev(LinkMode::Tlp);
        dev.mmio_write(0, 0x100, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let got = hdl.poll().unwrap();
        assert_eq!(got.len(), 1);
        let Msg::Tlp { bytes } = &got[0] else { panic!() };
        let t = Tlp::decode(bytes).unwrap();
        assert_eq!(
            t,
            Tlp::MemWr {
                addr: 0xF000_0100,
                data: vec![1, 2, 3, 4, 5, 6, 7, 8],
                requester: 0x0008
            }
        );
        // HDL-side MSI: MemWr to the FEE window.
        let msi = Tlp::MemWr {
            addr: tlp::MSI_WINDOW_BASE + 4, // vector 1
            data: vec![0; 4],
            requester: 0x0100,
        };
        hdl.send(&Msg::Tlp { bytes: msi.encode().unwrap() }).unwrap();
        let mut mem = TestMem(vec![0; 8]);
        let mut irq = TestIrq(vec![]);
        dev.poll_service(&mut mem, &mut irq).unwrap();
        assert_eq!(irq.0, vec![1]);
    }

    #[test]
    fn tlp_mode_read_fragments_and_reassembles() {
        let (mut dev, mut hdl) = mkdev(LinkMode::Tlp);
        dev.mmio_timeout = Duration::from_secs(2);
        let h = std::thread::spawn(move || {
            let mut served = 0;
            while served < 2 {
                for m in hdl.poll().unwrap() {
                    if let Msg::Tlp { bytes } = m {
                        if let Ok(Tlp::MemRd { addr, len_dw, tag, requester }) =
                            Tlp::decode(&bytes)
                        {
                            let data: Vec<u8> =
                                (0..len_dw as usize * 4).map(|i| (addr as u8) ^ i as u8).collect();
                            let c = Tlp::cpl_d(tag, 0, requester, data, 0, false).unwrap();
                            hdl.send(&Msg::Tlp { bytes: c.encode().unwrap() }).unwrap();
                            served += 1;
                        }
                    }
                }
                std::thread::sleep(Duration::from_micros(50));
            }
        });
        let mut mem = TestMem(vec![0; 8]);
        let mut irq = TestIrq(vec![]);
        // 512B read with 256B MPS → two MRd TLPs.
        let d = dev.mmio_read(0, 0, 512, &mut mem, &mut irq).unwrap();
        assert_eq!(d.len(), 512);
        h.join().unwrap();
        assert!(dev.stats.tlps_sent >= 2);
    }
}
