//! Deterministic PCIe fault injection.
//!
//! The paper's motivation is that driver/hardware bugs "cause the
//! system to hang, without providing enough information for
//! debugging". This module injects exactly those bugs, on purpose, at
//! exact transaction indices, so a failure is reproducible
//! bit-for-bit: a [`FaultPlan`] is a pure function of the CLI string
//! (`--fault k=completion-timeout@rec=3`), fires deterministically on
//! the device's **non-posted request clock** (the count of DMA read
//! requests the endpoint has initiated), records itself into the PR 8
//! frame recorder, and replays identically under `vmhdl replay`. A
//! device may carry a comma-separated *list* of plans
//! (`--fault k=completion-timeout@rec=2,completion-timeout@rec=4`);
//! each plan fires once at its own index.
//!
//! Fault classes (§ DEBUGGING.md §11 walks each one):
//!
//! * `completion-timeout` — the Nth DMA read request is dropped; no
//!   completion ever arrives. The bridge's read stays pending forever
//!   and the guest driver's cycle-based watchdog must fire.
//! * `poisoned-cpl` — the Nth DMA read completes with the EP
//!   ("poisoned data") bit set (TLP mode) or an aborted empty
//!   response (MMIO mode). The bridge converts it to SLVERR beats, the
//!   DMA engine latches an error, and the driver quarantines the
//!   record.
//! * `ur-status` — the Nth DMA read completes Unsupported Request:
//!   a data-less Cpl with status UR (TLP mode) / aborted response
//!   (MMIO mode).
//! * `surprise-down` — the link dies at the Nth DMA read and stays
//!   dead: the request is dropped, subsequent MMIO reads return
//!   all-ones (master abort), writes and MSIs are swallowed.
//! * `reset-inflight` — the *scenario* resets the device just before
//!   submitting record N with work still in flight; the driver must
//!   rebuild its rings and resubmit unacknowledged records exactly
//!   once. (No device-level action; see `coordinator/scenario.rs`.)
//! * `credit-starve` — the *bridge* freezes its flow-control credit
//!   pools for a fixed window at its Nth DMA read, stalling the data
//!   path without corrupting it (HDL-side; see `hdl/bridge.rs`).
//!
//! This file is in both `cargo xtask analyze` scopes: the determinism
//! pass (no wall clock, no ambient randomness — the fault clock is
//! the message stream itself) and the panic pass (plan strings come
//! from the CLI and from recorded file headers: malformed input must
//! surface as `Error::config`, never a panic).

use std::fmt;

use crate::{Error, Result};

/// One injectable fault class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Drop the Nth non-posted request: no completion, ever.
    CompletionTimeout,
    /// Link dead from the Nth non-posted request onward.
    SurpriseDown,
    /// Complete the Nth read with poisoned (EP) data.
    PoisonedCpl,
    /// Complete the Nth read with status UR, no data.
    UrStatus,
    /// Scenario-level: reset the device with records in flight.
    ResetInflight,
    /// Bridge-level: freeze flow-control credits for a window.
    CreditStarve,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::CompletionTimeout => "completion-timeout",
            FaultKind::SurpriseDown => "surprise-down",
            FaultKind::PoisonedCpl => "poisoned-cpl",
            FaultKind::UrStatus => "ur-status",
            FaultKind::ResetInflight => "reset-inflight",
            FaultKind::CreditStarve => "credit-starve",
        }
    }

    /// Stable numeric id, used by the snapshot geometry stamp.
    pub fn id(&self) -> u8 {
        match self {
            FaultKind::CompletionTimeout => 1,
            FaultKind::SurpriseDown => 2,
            FaultKind::PoisonedCpl => 3,
            FaultKind::UrStatus => 4,
            FaultKind::ResetInflight => 5,
            FaultKind::CreditStarve => 6,
        }
    }

    pub fn parse(s: &str) -> Result<FaultKind> {
        match s {
            "completion-timeout" => Ok(FaultKind::CompletionTimeout),
            "surprise-down" => Ok(FaultKind::SurpriseDown),
            "poisoned-cpl" => Ok(FaultKind::PoisonedCpl),
            "ur-status" => Ok(FaultKind::UrStatus),
            "reset-inflight" => Ok(FaultKind::ResetInflight),
            "credit-starve" => Ok(FaultKind::CreditStarve),
            other => Err(Error::config(format!(
                "unknown fault class {other:?} (expected completion-timeout, \
                 surprise-down, poisoned-cpl, ur-status, reset-inflight or \
                 credit-starve)"
            ))),
        }
    }
}

/// A per-device fault plan: fire `kind` at the `at`-th (1-based)
/// non-posted request the device observes. For the direct-mode sorter
/// a 256 B record is exactly one DMA read burst, so `rec=N` reads as
/// "the Nth record"; with SG rings descriptor fetches share the same
/// clock (documented in EXPERIMENTS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub kind: FaultKind,
    /// 1-based non-posted transaction index the fault fires at.
    pub at: u64,
}

impl FaultPlan {
    /// Parse a comma-separated plan list,
    /// `"<class>@rec=<n>[,<class>@rec=<m>...]"` — the full right-hand
    /// side of a `--fault k=...` override. Plans on one device fire
    /// independently, each on its own non-posted index.
    pub fn parse_list(s: &str) -> Result<Vec<FaultPlan>> {
        s.split(',').map(|p| FaultPlan::parse(p.trim())).collect()
    }

    /// Comma-joined [`Display`](fmt::Display) spelling of a plan list —
    /// the recording-header format. A single plan keeps the bare
    /// `class@rec=N` spelling, so pre-multi-fault recordings and their
    /// byte-exact header assertions are unchanged.
    pub fn format_list(plans: &[FaultPlan]) -> String {
        plans.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(",")
    }

    /// Parse `"<class>@rec=<n>"`, e.g. `completion-timeout@rec=3`.
    /// A bare `<class>` defaults to `rec=1`.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let (kind_s, at) = match s.split_once('@') {
            None => (s, 1),
            Some((k, rest)) => {
                let n = rest
                    .strip_prefix("rec=")
                    .ok_or_else(|| {
                        Error::config(format!(
                            "fault plan {s:?}: expected <class>@rec=<n>"
                        ))
                    })?
                    .parse::<u64>()
                    .map_err(|e| {
                        Error::config(format!("fault plan {s:?}: bad index ({e})"))
                    })?;
                (k, n)
            }
        };
        if at == 0 {
            return Err(Error::config(format!(
                "fault plan {s:?}: rec index is 1-based"
            )));
        }
        Ok(FaultPlan { kind: FaultKind::parse(kind_s)?, at })
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@rec={}", self.kind.name(), self.at)
    }
}

impl std::str::FromStr for FaultPlan {
    type Err = Error;
    fn from_str(s: &str) -> Result<FaultPlan> {
        FaultPlan::parse(s)
    }
}

/// What the pseudo device must do to the current non-posted request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Swallow the request; never complete it.
    DropRequest,
    /// Complete with poisoned (EP) data.
    PoisonCompletion,
    /// Complete with status UR and no data.
    UrCompletion,
}

/// Pick the one plan the HDL platform is elaborated with out of a
/// device's list: the bridge only acts on `credit-starve`, so the
/// first credit-starve plan wins; otherwise the first plan carries the
/// snapshot geometry stamp. Single-plan devices keep their plan either
/// way, so pre-multi-fault snapshots stay bit-compatible.
pub fn bridge_plan(plans: &[FaultPlan]) -> Option<FaultPlan> {
    plans
        .iter()
        .copied()
        .find(|p| p.kind == FaultKind::CreditStarve)
        .or_else(|| plans.first().copied())
}

/// Per-device fault runtime state: the non-posted request clock plus
/// the firing record. Pure function of the message stream — two runs
/// that see the same request sequence fire identically. A device may
/// carry several plans (`--fault k=classA@rec=N,classB@rec=M`); each
/// fires at most once, on its own index (the clock is monotonic), and
/// two plans on the same index resolve to the first listed.
#[derive(Debug, Clone, Default)]
pub struct FaultState {
    plans: Vec<FaultPlan>,
    /// Non-posted (DMA read) requests observed so far.
    pub nonposted_seen: u64,
    /// How many plans fired so far (surprise-down stays latched via
    /// `down`).
    pub fired: u64,
    down: bool,
    /// Human-readable description of what fired, for triage reports;
    /// multiple firings append with `"; "`.
    pub fired_desc: Option<String>,
}

impl FaultState {
    pub fn new(plan: Option<FaultPlan>) -> Self {
        FaultState::new_multi(plan.into_iter().collect())
    }

    /// Arm a full plan list (the multi-fault `--fault` form).
    pub fn new_multi(plans: Vec<FaultPlan>) -> Self {
        FaultState { plans, ..FaultState::default() }
    }

    /// The first armed plan, if any (legacy single-plan accessor).
    pub fn plan(&self) -> Option<FaultPlan> {
        self.plans.first().copied()
    }

    /// All armed plans.
    pub fn plans(&self) -> &[FaultPlan] {
        &self.plans
    }

    /// True once a surprise-down fault has fired: the link is dead.
    pub fn link_down(&self) -> bool {
        self.down
    }

    /// Advance the non-posted clock by one request (addr/len are for
    /// the triage description only) and return the action to apply to
    /// *this* request, if the plan fires on it.
    pub fn on_nonposted(&mut self, addr: u64, len: u32) -> Option<FaultAction> {
        self.nonposted_seen += 1;
        let seen = self.nonposted_seen;
        let plan = *self.plans.iter().find(|p| p.at == seen)?;
        let action = match plan.kind {
            FaultKind::CompletionTimeout => Some(FaultAction::DropRequest),
            FaultKind::SurpriseDown => {
                self.down = true;
                Some(FaultAction::DropRequest)
            }
            FaultKind::PoisonedCpl => Some(FaultAction::PoisonCompletion),
            FaultKind::UrStatus => Some(FaultAction::UrCompletion),
            // Scenario- and bridge-level classes do not act here.
            FaultKind::ResetInflight | FaultKind::CreditStarve => None,
        };
        if let Some(a) = action {
            self.fired += 1;
            let desc = format!(
                "{} fired at non-posted #{} (addr {addr:#x}, {len}B): {a:?}",
                plan.kind.name(),
                plan.at
            );
            match &mut self.fired_desc {
                Some(d) => {
                    d.push_str("; ");
                    d.push_str(&desc);
                }
                None => self.fired_desc = Some(desc),
            }
        }
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_all_classes() {
        for s in [
            "completion-timeout@rec=3",
            "surprise-down@rec=1",
            "poisoned-cpl@rec=5",
            "ur-status@rec=2",
            "reset-inflight@rec=4",
            "credit-starve@rec=7",
        ] {
            let p = FaultPlan::parse(s).unwrap();
            assert_eq!(p.to_string(), s);
            assert_eq!(FaultPlan::parse(&p.to_string()).unwrap(), p);
        }
    }

    #[test]
    fn parse_defaults_and_rejects() {
        assert_eq!(
            FaultPlan::parse("poisoned-cpl").unwrap(),
            FaultPlan { kind: FaultKind::PoisonedCpl, at: 1 }
        );
        assert!(FaultPlan::parse("poisoned-cpl@rec=0").is_err());
        assert!(FaultPlan::parse("poisoned-cpl@idx=3").is_err());
        assert!(FaultPlan::parse("nonsense@rec=1").is_err());
        assert!(FaultPlan::parse("").is_err());
    }

    #[test]
    fn fires_exactly_once_at_exact_index() {
        let mut st = FaultState::new(Some(FaultPlan::parse("ur-status@rec=3").unwrap()));
        assert_eq!(st.on_nonposted(0x1000, 256), None);
        assert_eq!(st.on_nonposted(0x2000, 256), None);
        assert_eq!(st.on_nonposted(0x3000, 256), Some(FaultAction::UrCompletion));
        assert_eq!(st.on_nonposted(0x4000, 256), None);
        assert_eq!(st.fired, 1);
        assert_eq!(st.nonposted_seen, 4);
        assert!(st.fired_desc.as_deref().unwrap().contains("ur-status"));
    }

    #[test]
    fn surprise_down_latches() {
        let mut st =
            FaultState::new(Some(FaultPlan::parse("surprise-down@rec=2").unwrap()));
        assert!(!st.link_down());
        st.on_nonposted(0, 4);
        assert!(!st.link_down());
        assert_eq!(st.on_nonposted(0, 4), Some(FaultAction::DropRequest));
        assert!(st.link_down());
    }

    #[test]
    fn parse_list_roundtrips_and_keeps_single_plan_spelling() {
        let spec = "completion-timeout@rec=2,poisoned-cpl@rec=5";
        let plans = FaultPlan::parse_list(spec).unwrap();
        assert_eq!(plans.len(), 2);
        assert_eq!(FaultPlan::format_list(&plans), spec);
        // Single plans keep the bare spelling (recording headers from
        // pre-multi-fault runs assert on it byte-exactly).
        let one = FaultPlan::parse_list("ur-status@rec=3").unwrap();
        assert_eq!(FaultPlan::format_list(&one), "ur-status@rec=3");
        assert!(FaultPlan::parse_list("ur-status@rec=3,").is_err());
        assert!(FaultPlan::parse_list("").is_err());
    }

    #[test]
    fn multi_plan_fires_each_plan_on_its_own_index() {
        let mut st = FaultState::new_multi(
            FaultPlan::parse_list("completion-timeout@rec=2,completion-timeout@rec=4")
                .unwrap(),
        );
        assert_eq!(st.on_nonposted(0x1000, 256), None);
        assert_eq!(st.on_nonposted(0x2000, 256), Some(FaultAction::DropRequest));
        assert_eq!(st.on_nonposted(0x3000, 256), None);
        assert_eq!(st.on_nonposted(0x4000, 256), Some(FaultAction::DropRequest));
        assert_eq!(st.on_nonposted(0x5000, 256), None);
        assert_eq!(st.fired, 2);
        let desc = st.fired_desc.as_deref().unwrap();
        assert!(desc.contains("#2") && desc.contains("#4"), "{desc}");
    }

    #[test]
    fn same_index_plans_resolve_to_the_first_listed() {
        let mut st = FaultState::new_multi(
            FaultPlan::parse_list("ur-status@rec=1,poisoned-cpl@rec=1").unwrap(),
        );
        assert_eq!(st.on_nonposted(0, 4), Some(FaultAction::UrCompletion));
        assert_eq!(st.fired, 1);
    }

    #[test]
    fn bridge_plan_prefers_credit_starve_then_first() {
        let plans =
            FaultPlan::parse_list("poisoned-cpl@rec=2,credit-starve@rec=3").unwrap();
        assert_eq!(bridge_plan(&plans).unwrap().kind, FaultKind::CreditStarve);
        let plans = FaultPlan::parse_list("poisoned-cpl@rec=2,ur-status@rec=3").unwrap();
        assert_eq!(bridge_plan(&plans).unwrap().kind, FaultKind::PoisonedCpl);
        assert_eq!(bridge_plan(&[]), None);
    }

    #[test]
    fn scenario_level_classes_do_not_act_on_device() {
        for s in ["reset-inflight@rec=1", "credit-starve@rec=1"] {
            let mut st = FaultState::new(Some(FaultPlan::parse(s).unwrap()));
            assert_eq!(st.on_nonposted(0, 4), None);
            assert_eq!(st.fired, 0);
        }
    }
}
