//! PCIe substrate: configuration space, BAR decode, MSI capability,
//! a TLP codec (for the vpcie-style low-level baseline of §V), and the
//! **PCIe FPGA pseudo device** — the VMM-side half of the co-simulation
//! link (paper §II).
//!
//! The pseudo device models the target FPGA board's PCIe personality
//! (the NetFPGA SUME in the paper): BAR count/sizes and MSI
//! capabilities, so the guest driver probes and binds to exactly what
//! it would see on real hardware.
//!
//! Layer map (guest-visible surface → link messages):
//!
//! * [`config_space`] — type-0 configuration header + MSI capability
//!   walker; what `lspci` would show for the board.
//! * [`bar`] — BAR sizing/decode ([`BarSet`]): routes a guest physical
//!   address to (BAR index, offset) the way the VMM's MMIO exits do.
//! * [`device`] — [`PcieFpgaDevice`], the pseudo device itself: turns
//!   guest MMIO into link messages, services HDL-initiated DMA against
//!   guest memory ([`DmaTarget`]) and forwards MSIs ([`IrqSink`])
//!   subject to the MSI enable/mask state the driver programmed.
//! * [`tlp`] — the raw transaction-layer-packet codec used by
//!   [`crate::link::LinkMode::Tlp`] to quantify the paper's §V
//!   argument against forwarding low-level PCIe messages.
//!
//! Nothing in here knows about the sorter or the HDL platform: the
//! boundary is exactly MMIO + DMA + MSI, which is what lets the same
//! guest driver run unmodified against physical hardware.

pub mod bar;
pub mod config_space;
pub mod device;
pub mod fault;
pub mod tlp;

pub use bar::{BarDef, BarKind, BarSet};
pub use config_space::{Bdf, BusAllocator, ConfigSpace};
pub use device::{DmaTarget, IrqSink, PcieFpgaDevice, PseudoDeviceStats};
pub use fault::{bridge_plan, FaultKind, FaultPlan, FaultState};
pub use tlp::Tlp;

/// The FPGA board personality used throughout (NetFPGA SUME-like).
pub mod board {
    /// Xilinx vendor id.
    pub const VENDOR_ID: u16 = 0x10EE;
    /// Device id used by the reference platform bitstream.
    pub const DEVICE_ID: u16 = 0x7028;
    /// BAR0: control/status + DMA registers (64 KiB, 32-bit, non-prefetchable).
    pub const BAR0_SIZE: u64 = 64 * 1024;
    /// BAR2: bulk window (1 MiB) — exercised by stress tests.
    pub const BAR2_SIZE: u64 = 1024 * 1024;
    /// Number of MSI vectors advertised.
    pub const MSI_VECTORS: u16 = 4;
    /// Subsystem id (NetFPGA SUME) — the sort-kernel personality the
    /// paper's bitstream reports.
    pub const SUBSYS_ID: u16 = 0x0007;
    /// Subsystem-id base for non-sort stream-kernel personalities:
    /// a bitstream carrying kernel id `k` (see
    /// [`crate::hdl::kernel::KernelKind::id`]) reports
    /// `KERNEL_SUBSYS_BASE | k`. The sort kernel keeps the original
    /// [`SUBSYS_ID`], so the default personality is bit-identical to
    /// the paper's board.
    pub const KERNEL_SUBSYS_BASE: u16 = 0x0100;

    /// The subsystem id a bitstream with stream-kernel id
    /// `kernel_id` reports. This is the config-space *hint* the driver
    /// cross-checks against the authoritative BAR0 capability register
    /// (`regfile::regs::KERNEL`) during probe — a mismatch means the
    /// enumerated personality and the RTL behind the bridge disagree
    /// (DEBUGGING.md §6).
    pub fn subsys_id_for_kernel(kernel_id: u32) -> u16 {
        match kernel_id {
            1 => SUBSYS_ID,
            k => KERNEL_SUBSYS_BASE | (k as u16 & 0xFF),
        }
    }

    /// Inverse of [`subsys_id_for_kernel`].
    pub fn kernel_id_for_subsys(subsys: u16) -> u32 {
        if subsys == SUBSYS_ID {
            1
        } else {
            (subsys & 0xFF) as u32
        }
    }
    /// Canonical guest-physical BAR placements (what the guest "BIOS"
    /// assigns at enumeration; the TLP-mode bridge needs them to
    /// reverse-map bus addresses — DESIGN.md documents this static
    /// assignment in lieu of forwarding CfgWr TLPs). These are the
    /// **device 0** placements; multi-device topologies stride per
    /// device — see [`bar0_gpa`] / [`bar2_gpa`].
    pub const BAR0_GPA: u64 = 0xF000_0000;
    pub const BAR2_GPA: u64 = 0xF800_0000;
    /// Per-device stride of the static BAR placement: each enumerated
    /// endpoint's windows sit `BAR_GPA_STRIDE` above the previous
    /// device's (1 MiB covers BAR0's 64 KiB and BAR2's full 1 MiB).
    pub const BAR_GPA_STRIDE: u64 = 0x10_0000;
    /// Maximum devices on the topology: bound by the 5-bit PCI device
    /// number (31 endpoints on bus 0 — device 0 is the host bridge),
    /// which is tighter than the 128 windows the static BAR layout
    /// could carve below `BAR2_GPA`.
    pub const MAX_DEVICES: usize = {
        let by_windows = ((BAR2_GPA - BAR0_GPA) / BAR_GPA_STRIDE) as usize;
        let by_bus = 31;
        if by_bus < by_windows {
            by_bus
        } else {
            by_windows
        }
    };

    /// BAR0 guest-physical base of device `index` on the shared bus.
    pub fn bar0_gpa(index: usize) -> u64 {
        assert!(index < MAX_DEVICES, "device index {index} out of range");
        BAR0_GPA + index as u64 * BAR_GPA_STRIDE
    }

    /// BAR2 guest-physical base of device `index`.
    pub fn bar2_gpa(index: usize) -> u64 {
        assert!(index < MAX_DEVICES, "device index {index} out of range");
        BAR2_GPA + index as u64 * BAR_GPA_STRIDE
    }
}
