//! PCIe substrate: configuration space, BAR decode, MSI capability,
//! a TLP codec (for the vpcie-style low-level baseline of §V), and the
//! **PCIe FPGA pseudo device** — the VMM-side half of the co-simulation
//! link (paper §II).
//!
//! The pseudo device models the target FPGA board's PCIe personality
//! (the NetFPGA SUME in the paper): BAR count/sizes and MSI
//! capabilities, so the guest driver probes and binds to exactly what
//! it would see on real hardware.
//!
//! Layer map (guest-visible surface → link messages):
//!
//! * [`config_space`] — type-0 configuration header + MSI capability
//!   walker; what `lspci` would show for the board.
//! * [`bar`] — BAR sizing/decode ([`BarSet`]): routes a guest physical
//!   address to (BAR index, offset) the way the VMM's MMIO exits do.
//! * [`device`] — [`PcieFpgaDevice`], the pseudo device itself: turns
//!   guest MMIO into link messages, services HDL-initiated DMA against
//!   guest memory ([`DmaTarget`]) and forwards MSIs ([`IrqSink`])
//!   subject to the MSI enable/mask state the driver programmed.
//! * [`tlp`] — the raw transaction-layer-packet codec used by
//!   [`crate::link::LinkMode::Tlp`] to quantify the paper's §V
//!   argument against forwarding low-level PCIe messages.
//!
//! Nothing in here knows about the sorter or the HDL platform: the
//! boundary is exactly MMIO + DMA + MSI, which is what lets the same
//! guest driver run unmodified against physical hardware.

pub mod bar;
pub mod config_space;
pub mod device;
pub mod tlp;

pub use bar::{BarDef, BarKind, BarSet};
pub use config_space::ConfigSpace;
pub use device::{DmaTarget, IrqSink, PcieFpgaDevice, PseudoDeviceStats};
pub use tlp::Tlp;

/// The FPGA board personality used throughout (NetFPGA SUME-like).
pub mod board {
    /// Xilinx vendor id.
    pub const VENDOR_ID: u16 = 0x10EE;
    /// Device id used by the reference platform bitstream.
    pub const DEVICE_ID: u16 = 0x7028;
    /// BAR0: control/status + DMA registers (64 KiB, 32-bit, non-prefetchable).
    pub const BAR0_SIZE: u64 = 64 * 1024;
    /// BAR2: bulk window (1 MiB) — exercised by stress tests.
    pub const BAR2_SIZE: u64 = 1024 * 1024;
    /// Number of MSI vectors advertised.
    pub const MSI_VECTORS: u16 = 4;
    /// Subsystem id (NetFPGA SUME).
    pub const SUBSYS_ID: u16 = 0x0007;
    /// Canonical guest-physical BAR placements (what the guest "BIOS"
    /// assigns at enumeration; the TLP-mode bridge needs them to
    /// reverse-map bus addresses — DESIGN.md documents this static
    /// assignment in lieu of forwarding CfgWr TLPs).
    pub const BAR0_GPA: u64 = 0xF000_0000;
    pub const BAR2_GPA: u64 = 0xF800_0000;
}
