//! PCI configuration space model (type-0 header + MSI capability),
//! plus the bus-level identity/allocation plumbing for multi-function
//! topologies: [`Bdf`] (bus/device/function) and [`BusAllocator`],
//! the enumeration-time allocator that hands each pseudo device a
//! unique BDF and non-overlapping guest-physical BAR windows.
//!
//! Implements the subset a guest driver exercises when probing and
//! binding the FPGA board: vendor/device id, command register, BAR
//! sizing protocol (write all-ones, read back the size mask), and the
//! MSI capability (enable bit, address, data, multiple-message bits).

use super::bar::{BarKind, BarSet};
use crate::{Error, Result};

/// A PCI bus/device/function address — the identity a config-space
/// function has on the bus, and the requester id it stamps on its
/// transactions.
///
/// Multi-device co-simulation: each of the N pseudo devices enumerated
/// by the VM gets its own `Bdf` from a [`BusAllocator`], so the guest
/// can tell the endpoints apart exactly as `lspci` would.
///
/// ```
/// use vmhdl::pcie::config_space::Bdf;
/// let bdf = Bdf::new(0, 3, 0);
/// assert_eq!(bdf.requester_id(), 3 << 3);
/// assert_eq!(bdf.to_string(), "00:03.0");
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Bdf {
    pub bus: u8,
    /// Device number (5 bits on real PCI).
    pub dev: u8,
    /// Function number (3 bits).
    pub func: u8,
}

impl Bdf {
    pub fn new(bus: u8, dev: u8, func: u8) -> Self {
        assert!(dev < 32 && func < 8, "BDF out of range: {dev}/{func}");
        Self { bus, dev, func }
    }

    /// The 16-bit requester/completer id carried in TLPs:
    /// `bus[15:8] | dev[7:3] | func[2:0]`.
    pub fn requester_id(self) -> u16 {
        ((self.bus as u16) << 8) | ((self.dev as u16) << 3) | self.func as u16
    }
}

impl std::fmt::Display for Bdf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:02x}:{:02x}.{}", self.bus, self.dev, self.func)
    }
}

/// Enumeration-time allocator: assigns sequential device numbers on a
/// bus and carves non-overlapping, naturally aligned guest-physical
/// windows for their BARs — the "BIOS" side of bringing up N endpoints
/// on one simulated PCIe topology.
///
/// ```
/// use vmhdl::pcie::config_space::BusAllocator;
/// let mut alloc = BusAllocator::new(0, 0xF000_0000);
/// let (bdf0, bars0) = alloc.alloc(&[64 * 1024, 1024 * 1024]);
/// let (bdf1, bars1) = alloc.alloc(&[64 * 1024, 1024 * 1024]);
/// assert_ne!(bdf0, bdf1);
/// // Windows never overlap and are size-aligned.
/// assert!(bars1[0] >= bars0[1] + 1024 * 1024);
/// assert_eq!(bars0[1] % (1024 * 1024), 0);
/// ```
#[derive(Debug, Clone)]
pub struct BusAllocator {
    bus: u8,
    next_dev: u8,
    next_base: u64,
}

impl BusAllocator {
    /// Allocate on `bus`, placing BAR windows upward from `mem_base`.
    pub fn new(bus: u8, mem_base: u64) -> Self {
        // Device 0 is conventionally the host bridge; endpoints start
        // at device 1.
        Self { bus, next_dev: 1, next_base: mem_base }
    }

    /// Allocate the next function: returns its BDF and one base per
    /// requested BAR size (aligned to the size, as hardware BARs are).
    pub fn alloc(&mut self, bar_sizes: &[u64]) -> (Bdf, Vec<u64>) {
        let bdf = Bdf::new(self.bus, self.next_dev, 0);
        self.next_dev += 1;
        let mut bases = Vec::with_capacity(bar_sizes.len());
        for &size in bar_sizes {
            let size = size.max(1).next_power_of_two();
            let base = (self.next_base + size - 1) & !(size - 1);
            bases.push(base);
            self.next_base = base + size;
        }
        (bdf, bases)
    }
}

/// Standard offsets.
pub mod regs {
    pub const VENDOR_ID: u16 = 0x00;
    pub const DEVICE_ID: u16 = 0x02;
    pub const COMMAND: u16 = 0x04;
    pub const STATUS: u16 = 0x06;
    pub const CLASS_REV: u16 = 0x08;
    pub const HEADER_TYPE: u16 = 0x0E;
    pub const BAR0: u16 = 0x10;
    pub const SUBSYS_VENDOR: u16 = 0x2C;
    pub const SUBSYS_ID: u16 = 0x2E;
    pub const CAP_PTR: u16 = 0x34;
    pub const INT_LINE: u16 = 0x3C;
    /// Where we place the MSI capability.
    pub const MSI_CAP: u16 = 0x50;
}

/// COMMAND register bits.
pub mod cmd {
    pub const MEM_ENABLE: u16 = 1 << 1;
    pub const BUS_MASTER: u16 = 1 << 2;
    pub const INTX_DISABLE: u16 = 1 << 10;
}

/// MSI capability state.
#[derive(Debug, Clone, Default)]
pub struct MsiState {
    pub enabled: bool,
    /// log2 of enabled vectors (Multiple Message Enable field).
    pub mme: u8,
    pub address: u64,
    pub data: u16,
}

impl MsiState {
    /// Number of vectors currently enabled.
    pub fn vectors(&self) -> u16 {
        1 << self.mme.min(5)
    }
}

/// A type-0 PCI function's configuration space.
pub struct ConfigSpace {
    raw: [u8; 256],
    bars: BarSet,
    /// Sizing latch: BAR slots whose last write was all-ones.
    sizing: [bool; 6],
    msi: MsiState,
    msi_cap_vectors: u16,
    /// Bus address of this function (default `00:00.0`; set by the
    /// enumerating VMM via [`ConfigSpace::with_bdf`]).
    bdf: Bdf,
}

impl ConfigSpace {
    pub fn new(
        vendor: u16,
        device: u16,
        subsys: u16,
        class_code: u32,
        bars: BarSet,
        msi_vectors: u16,
    ) -> Self {
        assert!(msi_vectors.is_power_of_two() && msi_vectors <= 32);
        let mut cs = Self {
            raw: [0; 256],
            bars,
            sizing: [false; 6],
            msi: MsiState::default(),
            msi_cap_vectors: msi_vectors,
            bdf: Bdf::default(),
        };
        cs.put16(regs::VENDOR_ID, vendor);
        cs.put16(regs::DEVICE_ID, device);
        cs.put32(regs::CLASS_REV, class_code << 8); // rev 0
        cs.raw[regs::HEADER_TYPE as usize] = 0x00;
        cs.put16(regs::SUBSYS_VENDOR, vendor);
        cs.put16(regs::SUBSYS_ID, subsys);
        // Status: capabilities list present.
        cs.put16(regs::STATUS, 1 << 4);
        cs.raw[regs::CAP_PTR as usize] = regs::MSI_CAP as u8;
        // MSI capability header: id 0x05, next 0, control.
        cs.raw[regs::MSI_CAP as usize] = 0x05;
        cs.raw[regs::MSI_CAP as usize + 1] = 0x00;
        let mmc = (msi_vectors as f32).log2() as u16;
        // Control: 64-bit capable (bit 7), MMC in bits 3:1.
        cs.put16(regs::MSI_CAP + 2, (1 << 7) | (mmc << 1));
        cs
    }

    fn put16(&mut self, off: u16, v: u16) {
        self.raw[off as usize..off as usize + 2].copy_from_slice(&v.to_le_bytes());
    }
    fn put32(&mut self, off: u16, v: u32) {
        self.raw[off as usize..off as usize + 4].copy_from_slice(&v.to_le_bytes());
    }
    fn get16(&self, off: u16) -> u16 {
        u16::from_le_bytes(self.raw[off as usize..off as usize + 2].try_into().unwrap())
    }

    /// Assign this function's bus address (builder style, used by the
    /// enumerating VMM).
    pub fn with_bdf(mut self, bdf: Bdf) -> Self {
        self.bdf = bdf;
        self
    }

    /// This function's bus/device/function address.
    pub fn bdf(&self) -> Bdf {
        self.bdf
    }

    pub fn bars(&self) -> &BarSet {
        &self.bars
    }
    pub fn bars_mut(&mut self) -> &mut BarSet {
        &mut self.bars
    }
    pub fn msi(&self) -> &MsiState {
        &self.msi
    }

    /// Memory decoding enabled (COMMAND.MEM)?
    pub fn mem_enabled(&self) -> bool {
        self.get16(regs::COMMAND) & cmd::MEM_ENABLE != 0
    }
    /// Bus mastering enabled (COMMAND.BME)? Gates device DMA.
    pub fn bus_master(&self) -> bool {
        self.get16(regs::COMMAND) & cmd::BUS_MASTER != 0
    }

    /// 32-bit aligned config read.
    pub fn read32(&self, off: u16) -> Result<u32> {
        if off as usize + 4 > 256 || off % 4 != 0 {
            return Err(Error::pcie(format!("bad config read at {off:#x}")));
        }
        let off_us = off as usize;
        // BAR reads: sizing protocol or live base.
        if (regs::BAR0..regs::BAR0 + 24).contains(&off) {
            let slot = ((off - regs::BAR0) / 4) as u8;
            return Ok(self.read_bar_slot(slot));
        }
        Ok(u32::from_le_bytes(self.raw[off_us..off_us + 4].try_into().unwrap()))
    }

    fn read_bar_slot(&self, slot: u8) -> u32 {
        // A slot is either a BAR's low word, a Mem64 BAR's high word,
        // or unimplemented (reads 0).
        if let Some(def) = self.bars.def_by_index(slot) {
            let base = self.bars.base(slot).unwrap_or(0);
            if self.sizing[slot as usize] {
                return (def.size_mask() as u32) | def.type_bits();
            }
            return (base as u32 & !0xF) | def.type_bits();
        }
        // High word of a preceding Mem64 BAR?
        if slot > 0 {
            if let Some(def) = self.bars.def_by_index(slot - 1) {
                if def.kind == BarKind::Mem64 {
                    let base = self.bars.base(slot - 1).unwrap_or(0);
                    if self.sizing[slot as usize] {
                        return (def.size_mask() >> 32) as u32;
                    }
                    return (base >> 32) as u32;
                }
            }
        }
        0
    }

    /// 32-bit aligned config write.
    pub fn write32(&mut self, off: u16, val: u32) -> Result<()> {
        if off as usize + 4 > 256 || off % 4 != 0 {
            return Err(Error::pcie(format!("bad config write at {off:#x}")));
        }
        match off {
            regs::COMMAND => {
                // STATUS (upper half) is RO here.
                let keep = cmd::MEM_ENABLE | cmd::BUS_MASTER | cmd::INTX_DISABLE;
                self.put16(regs::COMMAND, (val as u16) & keep);
            }
            o if (regs::BAR0..regs::BAR0 + 24).contains(&o) => {
                let slot = ((o - regs::BAR0) / 4) as u8;
                self.write_bar_slot(slot, val)?;
            }
            o if o == regs::MSI_CAP => {
                // Control word lives in the upper half of this dword.
                let ctrl = (val >> 16) as u16;
                self.msi.enabled = ctrl & 1 != 0;
                let mme = ((ctrl >> 4) & 0x7) as u8;
                let max_mmc = (self.msi_cap_vectors as f32).log2() as u8;
                self.msi.mme = mme.min(max_mmc);
                let mut c = self.get16(regs::MSI_CAP + 2);
                c = (c & !(1 | (0x7 << 4))) | (ctrl & 1) | (((self.msi.mme as u16) & 0x7) << 4);
                self.put16(regs::MSI_CAP + 2, c);
            }
            o if o == regs::MSI_CAP + 4 => {
                self.msi.address = (self.msi.address & !0xFFFF_FFFF) | val as u64;
                self.put32(o, val);
            }
            o if o == regs::MSI_CAP + 8 => {
                self.msi.address = (self.msi.address & 0xFFFF_FFFF) | ((val as u64) << 32);
                self.put32(o, val);
            }
            o if o == regs::MSI_CAP + 12 => {
                self.msi.data = val as u16;
                self.put32(o, val);
            }
            regs::VENDOR_ID | regs::CLASS_REV | regs::SUBSYS_VENDOR => {} // RO
            _ => self.put32(off, val),
        }
        Ok(())
    }

    fn write_bar_slot(&mut self, slot: u8, val: u32) -> Result<()> {
        if let Some(def) = self.bars.def_by_index(slot) {
            let size = def.size;
            if val == u32::MAX {
                self.sizing[slot as usize] = true;
                return Ok(());
            }
            self.sizing[slot as usize] = false;
            let old = self.bars.base(slot).unwrap_or(0);
            let base = (old & !0xFFFF_FFFF) | (val as u64 & !0xF);
            // Align down — hardware BAR registers hardwire low bits.
            return self.bars.set_base(slot, base & !(size - 1));
        }
        // High word of Mem64 BAR.
        if slot > 0 {
            let info = self.bars.def_by_index(slot - 1).map(|d| (d.kind, d.size));
            if let Some((BarKind::Mem64, _)) = info {
                if val == u32::MAX {
                    self.sizing[slot as usize] = true;
                    return Ok(());
                }
                self.sizing[slot as usize] = false;
                let old = self.bars.base(slot - 1).unwrap_or(0);
                let base = (old & 0xFFFF_FFFF) | ((val as u64) << 32);
                return self.bars.set_base(slot - 1, base);
            }
        }
        Ok(()) // writes to unimplemented BARs are ignored
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcie::bar::{BarDef, BarKind, BarSet};
    use crate::pcie::board;

    fn dev() -> ConfigSpace {
        ConfigSpace::new(
            board::VENDOR_ID,
            board::DEVICE_ID,
            board::SUBSYS_ID,
            0x058000, // memory controller class, as Xilinx ref designs use
            BarSet::new(vec![
                BarDef::new(0, board::BAR0_SIZE, BarKind::Mem32),
                BarDef::new(2, board::BAR2_SIZE, BarKind::Mem64),
            ]),
            board::MSI_VECTORS,
        )
    }

    #[test]
    fn ids_read_back() {
        let d = dev();
        let id = d.read32(regs::VENDOR_ID).unwrap();
        assert_eq!(id & 0xFFFF, board::VENDOR_ID as u32);
        assert_eq!(id >> 16, board::DEVICE_ID as u32);
    }

    #[test]
    fn bar_sizing_protocol() {
        let mut d = dev();
        // Probe BAR0: write all-ones, read size mask, restore base.
        d.write32(regs::BAR0, u32::MAX).unwrap();
        let mask = d.read32(regs::BAR0).unwrap();
        let size = !(mask & !0xF) as u64 + 1;
        assert_eq!(size, board::BAR0_SIZE);
        d.write32(regs::BAR0, 0xF000_0000).unwrap();
        assert_eq!(d.read32(regs::BAR0).unwrap() & !0xF, 0xF000_0000);
        assert_eq!(d.bars().base(0), Some(0xF000_0000));
    }

    #[test]
    fn bar64_sizing_and_assign() {
        let mut d = dev();
        let slot_lo = regs::BAR0 + 8; // BAR2
        let slot_hi = regs::BAR0 + 12; // BAR3 = high half
        d.write32(slot_lo, u32::MAX).unwrap();
        d.write32(slot_hi, u32::MAX).unwrap();
        let lo = d.read32(slot_lo).unwrap();
        let hi = d.read32(slot_hi).unwrap();
        let mask = ((hi as u64) << 32) | (lo as u64 & !0xF);
        assert_eq!(!mask + 1, board::BAR2_SIZE);
        // Assign a >4G base.
        d.write32(slot_lo, 0x0010_0000).unwrap();
        d.write32(slot_hi, 0x1).unwrap();
        assert_eq!(d.bars().base(2), Some(0x1_0010_0000));
        // BAR reads reflect the 64-bit base.
        assert_eq!(d.read32(slot_hi).unwrap(), 0x1);
    }

    #[test]
    fn command_gates() {
        let mut d = dev();
        assert!(!d.mem_enabled());
        assert!(!d.bus_master());
        d.write32(regs::COMMAND, (cmd::MEM_ENABLE | cmd::BUS_MASTER) as u32)
            .unwrap();
        assert!(d.mem_enabled());
        assert!(d.bus_master());
    }

    #[test]
    fn msi_enable_flow() {
        let mut d = dev();
        // Guest writes address/data then sets enable + MME=1 (2 vectors).
        d.write32(regs::MSI_CAP + 4, 0xFEE0_0000).unwrap();
        d.write32(regs::MSI_CAP + 8, 0).unwrap();
        d.write32(regs::MSI_CAP + 12, 0x4041).unwrap();
        d.write32(regs::MSI_CAP, (1 | (1 << 4)) << 16).unwrap();
        let m = d.msi();
        assert!(m.enabled);
        assert_eq!(m.vectors(), 2);
        assert_eq!(m.address, 0xFEE0_0000);
        assert_eq!(m.data, 0x4041);
    }

    #[test]
    fn msi_mme_clamped_to_capability() {
        let mut d = dev();
        // Ask for 32 vectors (MME=5); device only advertises 4 (MMC=2).
        d.write32(regs::MSI_CAP, (1 | (5 << 4)) << 16).unwrap();
        assert_eq!(d.msi().vectors(), board::MSI_VECTORS);
    }

    #[test]
    fn ro_regs_ignore_writes() {
        let mut d = dev();
        d.write32(regs::VENDOR_ID, 0xdead_beef).unwrap();
        let id = d.read32(regs::VENDOR_ID).unwrap();
        assert_eq!(id & 0xFFFF, board::VENDOR_ID as u32);
    }

    #[test]
    fn unaligned_rejected() {
        let d = dev();
        assert!(d.read32(2).is_err());
        assert!(d.read32(254).is_err());
    }

    #[test]
    fn bdf_requester_id_and_display() {
        let bdf = Bdf::new(1, 2, 3);
        assert_eq!(bdf.requester_id(), (1 << 8) | (2 << 3) | 3);
        assert_eq!(bdf.to_string(), "01:02.3");
        let d = dev().with_bdf(bdf);
        assert_eq!(d.bdf(), bdf);
    }

    #[test]
    fn bus_allocator_unique_bdfs_and_disjoint_windows() {
        let mut alloc = BusAllocator::new(0, board::BAR0_GPA);
        let mut seen = Vec::new();
        let mut prev_end = 0u64;
        for _ in 0..4 {
            let (bdf, bases) = alloc.alloc(&[board::BAR0_SIZE, board::BAR2_SIZE]);
            assert!(!seen.contains(&bdf), "duplicate BDF {bdf}");
            seen.push(bdf);
            assert_eq!(bases.len(), 2);
            for (&base, &size) in bases.iter().zip([board::BAR0_SIZE, board::BAR2_SIZE].iter()) {
                assert_eq!(base % size, 0, "BAR base {base:#x} unaligned to {size:#x}");
                assert!(base >= prev_end, "window overlap at {base:#x}");
                prev_end = base + size;
            }
        }
    }
}
