//! Golden-model runtime: pluggable reference backends for checking
//! (and functionally replacing) the cycle-accurate RTL datapath.
//!
//! Two roles, independent of the backend in use:
//!
//! * **Golden model** — after every co-simulated offload the
//!   coordinator replays the input through the reference sort and
//!   compares bit-for-bit with what the RTL wrote back to guest
//!   memory ([`GoldenBackend::check_sorted`]).
//! * **Functional fast mode** — the same backend serves as the
//!   functional-level accelerator datapath (`--mode func` / the
//!   `vmhdl golden` subcommand), giving the "functional correctness
//!   without cycle accuracy" point the paper makes in §IV-C.
//!
//! Backends:
//!
//! * [`NativeGolden`] (**default**) — a pure-Rust bitonic-network
//!   reference sort mirroring `python/compile/kernels/ref.py`. Always
//!   compiled, needs no artifacts, no Python, no external libraries:
//!   this is what makes `cargo build --release && cargo test -q` work
//!   on a clean checkout.
//! * `PjrtGolden` (behind the `pjrt` cargo feature) — compiles the
//!   HLO-text artifacts lowered by `python/compile/aot.py` from the
//!   L2 jax model (which calls the L1 Pallas bitonic-network kernel)
//!   on a PJRT CPU client, closing the loop RTL == artifact ==
//!   kernel == reference. Requires the `xla` bindings at build time
//!   and `make artifacts` at run time; select it with
//!   `--backend pjrt`.
//!
//! Both backends implement the same order-invariant record checksum
//! contract (`python/compile/model.py::record_checksum`): int64 sum of
//! the record xor-mixed with the int32 xor-fold in the high 32 bits.

use std::path::Path;
use std::time::Duration;

use crate::{Error, Result};

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::NativeGolden;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtGolden;

/// Summary statistics of one record — the golden op behind the HDL
/// stats stream kernel ([`crate::hdl::kernel::KernelKind::Stats`]).
/// The wire layout of the corresponding completion is
/// [`crate::hdl::kernel::pack_stats_words`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsSummary {
    pub min: i32,
    pub max: i32,
    pub sum: i64,
    pub count: u32,
}

/// Cumulative cost accounting of a backend (all backends report the
/// same shape so scenario output stays comparable across them).
#[derive(Debug, Clone, Copy, Default)]
pub struct BackendStats {
    /// Reference executions performed (one per record batch dispatched
    /// to the underlying engine).
    pub executions: u64,
    /// One-time preparation cost (PJRT: HLO→executable compilation;
    /// native: zero).
    pub compile_wall: Duration,
    /// Cumulative execution wall time.
    pub exec_wall: Duration,
}

/// A golden-model backend: the functional twin of the RTL sorter.
///
/// The contract every backend must satisfy, for records of exactly
/// [`n`](GoldenBackend::n) 32-bit words:
///
/// * [`sort_i32`](GoldenBackend::sort_i32) returns each record sorted
///   along its length (ascending, or descending when asked) —
///   bit-identical to `python/compile/kernels/ref.py`;
/// * [`checksum`](GoldenBackend::checksum) is order-invariant over a
///   record's words and follows
///   `python/compile/model.py::record_checksum` exactly, so checksums
///   computed by different backends (or by the python side) pair up.
///
/// # Example
///
/// ```
/// use vmhdl::runtime::{GoldenBackend, NativeGolden};
///
/// let mut golden = NativeGolden::new(8).unwrap();
/// let record = vec![5, 3, 7, 1, 0, -2, 9, 4];
///
/// // Functional fast mode: sort without any HDL simulation.
/// let sorted = golden.sort_i32(&[record.clone()], false).unwrap();
/// assert_eq!(sorted[0], vec![-2, 0, 1, 3, 4, 5, 7, 9]);
///
/// // Golden check: would flag any RTL result that mismatches.
/// golden.check_sorted(&record, &sorted[0], false).unwrap();
/// assert!(golden.check_sorted(&record, &record, false).is_err());
///
/// // Checksums are order-invariant (input pairs with its output).
/// let a = golden.checksum(&record).unwrap();
/// let b = golden.checksum(&sorted[0]).unwrap();
/// assert_eq!(a, b);
/// ```
pub trait GoldenBackend {
    /// Short backend identifier (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Record length (32-bit words) this backend instance serves.
    fn n(&self) -> usize;

    /// Sort a batch of records (each exactly `n` i32 words).
    fn sort_i32(&mut self, records: &[Vec<i32>], descending: bool) -> Result<Vec<Vec<i32>>>;

    /// Order-invariant record checksum (used by the coordinator to
    /// pair DMA input/output buffers without retaining full inputs).
    fn checksum(&mut self, record: &[i32]) -> Result<i64>;

    /// Summary statistics (min/max/sum/count) of a record — the golden
    /// twin of the HDL stats stream kernel. The default follows the
    /// shared spec ([`native::record_stats`]); backends with their own
    /// engine may override, but must agree bit-for-bit.
    fn stats_summary(&mut self, record: &[i32]) -> Result<StatsSummary> {
        if record.len() != self.n() {
            return Err(Error::runtime(format!(
                "stats: record has {} words, backend is for n={}",
                record.len(),
                self.n()
            )));
        }
        Ok(native::record_stats(record))
    }

    /// Cumulative cost accounting.
    fn stats(&self) -> BackendStats;

    /// Golden check: does `output` equal the reference-sorted `input`?
    /// Returns the first mismatching index on failure.
    fn check_sorted(&mut self, input: &[i32], output: &[i32], descending: bool) -> Result<()> {
        let golden = self.sort_i32(std::slice::from_ref(&input.to_vec()), descending)?;
        if golden[0] != output {
            let pos = golden[0]
                .iter()
                .zip(output)
                .position(|(a, b)| a != b)
                .unwrap_or(0);
            return Err(Error::runtime(format!(
                "golden mismatch at word {pos}: hdl={} {}={}",
                output.get(pos).copied().unwrap_or(0),
                self.name(),
                golden[0][pos]
            )));
        }
        Ok(())
    }

    /// The functional fast mode: answer a whole "offload" purely in
    /// the reference model (input records → sorted records), bypassing
    /// the HDL simulation. Used by `vmhdl golden` and the `--mode
    /// func` benches to quantify the cycle-accuracy cost.
    fn func_offload(&mut self, records: &[Vec<i32>], descending: bool) -> Result<Vec<Vec<i32>>> {
        self.sort_i32(records, descending)
    }
}

/// Which golden-model backend to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust bitonic reference (always available).
    #[default]
    Native,
    /// AOT XLA via PJRT (needs the `pjrt` cargo feature + artifacts).
    Pjrt,
}

impl std::str::FromStr for BackendKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(Error::config(format!(
                "unknown golden backend {other:?} (expected \"native\" or \"pjrt\")"
            ))),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        })
    }
}

/// Instantiate a golden-model backend.
///
/// `artifacts` is only consulted by the PJRT backend (the native
/// backend is self-contained). Asking for [`BackendKind::Pjrt`] in a
/// build without the `pjrt` feature fails with a pointer to the
/// rebuild command rather than at link time, so the default build
/// never references the `xla` crate.
pub fn load_backend(
    kind: BackendKind,
    artifacts: &Path,
    n: usize,
) -> Result<Box<dyn GoldenBackend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(NativeGolden::new(n)?)),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Box::new(PjrtGolden::load(artifacts, n)?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => {
            let _ = artifacts;
            Err(Error::runtime(
                "backend \"pjrt\" requires a build with `--features pjrt` \
                 (and `make artifacts` for the HLO files) — see README.md \
                 §Golden-model backends",
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Native);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("bogus".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::default().to_string(), "native");
    }

    #[test]
    fn native_loads_without_artifacts() {
        let g = load_backend(BackendKind::Native, Path::new("/nonexistent"), 1024).unwrap();
        assert_eq!(g.name(), "native");
        assert_eq!(g.n(), 1024);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_fails_with_guidance() {
        let err = match load_backend(BackendKind::Pjrt, Path::new("/nonexistent"), 1024) {
            Err(e) => e,
            Ok(_) => panic!("pjrt backend must be unavailable without the feature"),
        };
        assert!(err.to_string().contains("--features pjrt"), "{err}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn native_and_pjrt_agree() {
        // Cross-backend smoke test: both reference implementations of
        // the same contract must agree bit-for-bit on sorts and
        // checksums. Needs `make artifacts` (skipped loudly if absent).
        use crate::testutil::XorShift64;
        let artifacts =
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let mut pjrt = PjrtGolden::load(&artifacts, 1024)
            .expect("pjrt feature enabled but artifacts missing — run `make artifacts`");
        let mut native = NativeGolden::new(1024).unwrap();
        let mut rng = XorShift64::new(0xA62EE);
        let records: Vec<Vec<i32>> = (0..3).map(|_| rng.vec_i32(1024)).collect();
        for descending in [false, true] {
            let a = native.sort_i32(&records, descending).unwrap();
            let b = pjrt.sort_i32(&records, descending).unwrap();
            assert_eq!(a, b, "backends disagree (descending={descending})");
        }
        for r in &records {
            assert_eq!(
                native.checksum(r).unwrap(),
                pjrt.checksum(r).unwrap(),
                "checksum contract drifted between backends"
            );
        }
    }
}
