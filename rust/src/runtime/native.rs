//! Native golden backend: a pure-Rust bitonic-network reference sort.
//!
//! This is the zero-dependency twin of `python/compile/kernels/ref.py`
//! — the oracle every other implementation must agree with. It
//! deliberately does **not** reuse [`crate::hdl::sorter::bitonic_sort_i32`]:
//! the RTL model iterates the network run-by-run (the §Perf-tuned
//! formulation), while this backend evaluates the classic lane-scan
//! `i ^ j` formulation. Two independently written networks agreeing
//! with each other *and* with `sort_unstable` is what the property
//! test below buys; a shared helper would make it a tautology.
//!
//! The checksum follows `python/compile/model.py::record_checksum`
//! bit-for-bit (int32 xor-fold in the high 32 bits, xor-mixed with the
//! int64 element sum), so native and PJRT checksums pair up.

use std::time::{Duration, Instant};

use super::{BackendStats, GoldenBackend};
use crate::{Error, Result};

/// Bitonic sorting network, lane-scan formulation: for every stage
/// `(k, j)` visit all lanes and compare-exchange `i` with `i ^ j`
/// (once per pair, `partner > i`), direction given by `i & k`.
pub fn bitonic_network_sort(data: &mut [i32], descending: bool) {
    let n = data.len();
    debug_assert!(n.is_power_of_two(), "bitonic network needs power-of-two n");
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j >= 1 {
            for i in 0..n {
                let partner = i ^ j;
                if partner > i {
                    let up = ((i & k) == 0) != descending;
                    if (data[i] > data[partner]) == up {
                        data.swap(i, partner);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
}

/// Order-invariant record checksum — the exact contract of
/// `python/compile/model.py::record_checksum`.
pub fn record_checksum(record: &[i32]) -> i64 {
    let sum: i64 = record.iter().map(|&v| v as i64).sum();
    let xor: i32 = record.iter().fold(0, |a, &b| a ^ b);
    ((xor as i64) << 32) ^ sum
}

/// Summary statistics of a record (min/max/sum/count) — the golden op
/// the HDL stats stream kernel must agree with bit-for-bit. `sum` is
/// accumulated in i64, so it cannot wrap for any record length this
/// framework supports.
pub fn record_stats(record: &[i32]) -> crate::runtime::StatsSummary {
    let mut min = i32::MAX;
    let mut max = i32::MIN;
    let mut sum = 0i64;
    for &v in record {
        min = min.min(v);
        max = max.max(v);
        sum += v as i64;
    }
    crate::runtime::StatsSummary { min, max, sum, count: record.len() as u32 }
}

/// The pure-Rust golden backend (default). Self-contained: no
/// artifacts, no Python, no external libraries.
pub struct NativeGolden {
    n: usize,
    executions: u64,
    exec_wall: Duration,
}

impl NativeGolden {
    /// Create a backend for records of `n` 32-bit words. `n` must be a
    /// power of two (the sorting network's shape), like the RTL sorter.
    pub fn new(n: usize) -> Result<Self> {
        if !n.is_power_of_two() || n == 0 {
            return Err(Error::runtime(format!(
                "native backend needs a power-of-two record length, got {n}"
            )));
        }
        Ok(Self {
            n,
            executions: 0,
            exec_wall: Duration::ZERO,
        })
    }
}

impl GoldenBackend for NativeGolden {
    fn name(&self) -> &'static str {
        "native"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn sort_i32(&mut self, records: &[Vec<i32>], descending: bool) -> Result<Vec<Vec<i32>>> {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(records.len());
        for (idx, r) in records.iter().enumerate() {
            if r.len() != self.n {
                return Err(Error::runtime(format!(
                    "record {idx} has {} words, backend is for n={}",
                    r.len(),
                    self.n
                )));
            }
            let mut sorted = r.clone();
            bitonic_network_sort(&mut sorted, descending);
            out.push(sorted);
        }
        self.executions += 1;
        self.exec_wall += t0.elapsed();
        Ok(out)
    }

    fn checksum(&mut self, record: &[i32]) -> Result<i64> {
        if record.len() != self.n {
            return Err(Error::runtime("checksum: wrong record length"));
        }
        let t0 = Instant::now();
        let c = record_checksum(record);
        self.exec_wall += t0.elapsed();
        self.executions += 1;
        Ok(c)
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            executions: self.executions,
            compile_wall: Duration::ZERO,
            exec_wall: self.exec_wall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdl::sorter::bitonic_sort_i32;
    use crate::testutil::{forall, XorShift64};

    fn model() -> NativeGolden {
        NativeGolden::new(1024).unwrap()
    }

    #[test]
    fn sort_matches_std() {
        let mut m = model();
        let mut rng = XorShift64::new(11);
        let rec = rng.vec_i32(1024);
        let got = m.sort_i32(&[rec.clone()], false).unwrap();
        let mut expect = rec;
        expect.sort_unstable();
        assert_eq!(got[0], expect);
    }

    #[test]
    fn sort_descending_and_batches() {
        let mut m = model();
        let mut rng = XorShift64::new(12);
        let records: Vec<Vec<i32>> = (0..9).map(|_| rng.vec_i32(1024)).collect();
        let got = m.sort_i32(&records, true).unwrap();
        assert_eq!(got.len(), 9);
        for (g, r) in got.iter().zip(&records) {
            let mut e = r.clone();
            e.sort_unstable();
            e.reverse();
            assert_eq!(g, &e);
        }
        assert!(m.stats().executions >= 1);
    }

    #[test]
    fn check_sorted_catches_corruption() {
        let mut m = model();
        let mut rng = XorShift64::new(13);
        let rec = rng.vec_i32(1024);
        let mut sorted = rec.clone();
        sorted.sort_unstable();
        m.check_sorted(&rec, &sorted, false).unwrap();
        sorted[100] ^= 1;
        let err = m.check_sorted(&rec, &sorted, false).unwrap_err();
        assert!(err.to_string().contains("golden mismatch"), "{err}");
    }

    #[test]
    fn checksum_is_order_invariant() {
        let mut m = model();
        let mut rng = XorShift64::new(14);
        let rec = rng.vec_i32(1024);
        let mut shuffled = rec.clone();
        shuffled.reverse();
        assert_eq!(m.checksum(&rec).unwrap(), m.checksum(&shuffled).unwrap());
        let mut other = rec.clone();
        other[5] ^= 3;
        assert_ne!(m.checksum(&rec).unwrap(), m.checksum(&other).unwrap());
    }

    #[test]
    fn checksum_matches_python_contract() {
        // Hand-computed against model.py::record_checksum semantics:
        // sum in i64, xor-fold in i32 widened into the high 32 bits.
        let rec = [1i32, 2, 3, -4];
        let sum = 1 + 2 + 3 - 4i64; // 2
        let xor = 1 ^ 2 ^ 3 ^ -4i32;
        assert_eq!(record_checksum(&rec), ((xor as i64) << 32) ^ sum);
        // A value edit must not cancel between the sum and xor halves.
        let mut edited = rec;
        edited[0] ^= 1 << 30;
        assert_ne!(record_checksum(&rec), record_checksum(&edited));
    }

    #[test]
    fn stats_summary_matches_a_naive_scan() {
        use crate::runtime::GoldenBackend as _;
        let mut m = model();
        let mut rng = XorShift64::new(15);
        let rec = rng.vec_i32(1024);
        let s = m.stats_summary(&rec).unwrap();
        assert_eq!(s.min, *rec.iter().min().unwrap());
        assert_eq!(s.max, *rec.iter().max().unwrap());
        assert_eq!(s.sum, rec.iter().map(|&v| v as i64).sum::<i64>());
        assert_eq!(s.count, 1024);
        // Order-invariant, like the checksum.
        let mut rev = rec.clone();
        rev.reverse();
        assert_eq!(m.stats_summary(&rev).unwrap(), s);
        assert!(m.stats_summary(&[1, 2, 3]).is_err());
    }

    #[test]
    fn wrong_length_is_an_error_not_a_panic() {
        let mut m = model();
        assert!(m.sort_i32(&[vec![1, 2, 3]], false).is_err());
        assert!(m.checksum(&[1, 2, 3]).is_err());
        assert!(NativeGolden::new(1000).is_err(), "1000 is not a power of two");
        assert!(NativeGolden::new(0).is_err());
    }

    #[test]
    fn prop_native_network_matches_hdl_network_and_std() {
        // The cross-implementation property the backend exists for:
        // the lane-scan network here, the run-based network in
        // hdl/sorter.rs, and std's sort must agree on random batches
        // of random power-of-two sizes, both directions.
        forall(
            0x601DE2,
            40,
            |g| {
                let n = 1usize << g.rng.range(0, 10); // 1..=1024
                let records = g.rng.range(1, 4);
                let descending = g.rng.chance(1, 2);
                let data: Vec<Vec<i32>> =
                    (0..records).map(|_| g.rng.vec_i32(n)).collect();
                (n, descending, data)
            },
            |(n, descending, data)| {
                let mut m = NativeGolden::new(*n).map_err(|e| e.to_string())?;
                let native = m.sort_i32(data, *descending).map_err(|e| e.to_string())?;
                for (i, (got, input)) in native.iter().zip(data).enumerate() {
                    let mut expect = input.clone();
                    expect.sort_unstable();
                    if *descending {
                        expect.reverse();
                    }
                    if got != &expect {
                        return Err(format!("record {i}: native != std sort"));
                    }
                    let mut hdl = input.clone();
                    bitonic_sort_i32(&mut hdl, *descending);
                    if got != &hdl {
                        return Err(format!("record {i}: native != hdl network"));
                    }
                }
                Ok(())
            },
        );
    }
}
