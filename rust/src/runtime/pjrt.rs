//! PJRT golden backend (`pjrt` cargo feature): load the AOT-compiled
//! XLA artifacts and run them from the rust side (no python anywhere
//! near the request path).
//!
//! The artifacts are lowered once by `python/compile/aot.py` from the
//! L2 jax model (which calls the L1 Pallas bitonic-network kernel) to
//! **HLO text** — the id-safe interchange format for the pinned
//! xla_extension (jax emits 64-bit instruction ids the extension's
//! proto parser rejects; the text parser reassigns them — see the
//! `aot.py` module docstring) — and compiled here on the PJRT CPU
//! client at first use. Build with `--features pjrt` and run
//! `make artifacts` once; the default (native) backend needs neither.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use super::{BackendStats, GoldenBackend};
use crate::{Error, Result};

/// Artifact naming scheme (mirrors aot.py).
fn artifact_name(kind: &str, batch: usize, n: usize, dtype: &str) -> String {
    format!("{kind}_{batch}x{n}_{dtype}.hlo.txt")
}

/// The PJRT-backed golden model / functional accelerator.
pub struct PjrtGolden {
    client: xla::PjRtClient,
    dir: PathBuf,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Record length (words) the artifacts were lowered for.
    pub n: usize,
    /// Batch sizes available on disk (prefer the largest that fits).
    pub batches: Vec<usize>,
    pub executions: u64,
    pub compile_wall: Duration,
    pub exec_wall: Duration,
}

impl PjrtGolden {
    /// Open the artifacts directory and the PJRT CPU client. Fails
    /// fast (with a pointer to `make artifacts`) if artifacts are
    /// missing.
    pub fn load(dir: &Path, n: usize) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        if !manifest.exists() {
            return Err(Error::runtime(format!(
                "no artifacts at {} — run `make artifacts` first",
                dir.display()
            )));
        }
        let client = xla::PjRtClient::cpu()?;
        // Discover available batch sizes for the sort artifact.
        let mut batches: Vec<usize> = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(rest) = name.strip_prefix("sort_") {
                if let Some(bx) = rest.strip_suffix(&format!("x{n}_i32.hlo.txt")) {
                    if let Ok(b) = bx.parse::<usize>() {
                        batches.push(b);
                    }
                }
            }
        }
        batches.sort_unstable();
        if batches.is_empty() {
            return Err(Error::runtime(format!(
                "no sort_*x{n}_i32 artifacts in {}",
                dir.display()
            )));
        }
        Ok(Self {
            client,
            dir: dir.to_path_buf(),
            exes: HashMap::new(),
            n,
            batches,
            executions: 0,
            compile_wall: Duration::ZERO,
            exec_wall: Duration::ZERO,
        })
    }

    /// Compile (once) and fetch an executable by artifact file name.
    fn exe(&mut self, fname: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(fname) {
            let path = self.dir.join(fname);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::runtime("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compile_wall += t0.elapsed();
            self.exes.insert(fname.to_string(), exe);
        }
        Ok(&self.exes[fname])
    }

    fn sort_impl(&mut self, records: &[Vec<i32>], descending: bool) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(records.len());
        let mut idx = 0;
        while idx < records.len() {
            let remaining = records.len() - idx;
            // Largest artifact batch ≤ remaining (or the smallest one,
            // padded, if remaining is smaller than all).
            let b = *self
                .batches
                .iter()
                .rev()
                .find(|&&b| b <= remaining)
                .unwrap_or(&self.batches[0]);
            let kind = if descending { "sort_desc" } else { "sort" };
            let fname = artifact_name(kind, b, self.n, "i32");
            let take = b.min(remaining);
            // Flatten (padding the tail batch by repeating record 0).
            let mut flat: Vec<i32> = Vec::with_capacity(b * self.n);
            for i in 0..b {
                let r = if i < take { &records[idx + i] } else { &records[idx] };
                if r.len() != self.n {
                    return Err(Error::runtime(format!(
                        "record {} has {} words, artifacts are for n={}",
                        idx + i,
                        r.len(),
                        self.n
                    )));
                }
                flat.extend_from_slice(r);
            }
            let n = self.n;
            let t0 = std::time::Instant::now();
            let exe = self.exe(&fname)?;
            let lit = xla::Literal::vec1(&flat).reshape(&[b as i64, n as i64])?;
            let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let tuple = result.to_tuple1()?;
            let vals = tuple.to_vec::<i32>()?;
            self.exec_wall += t0.elapsed();
            self.executions += 1;
            for i in 0..take {
                out.push(vals[i * n..(i + 1) * n].to_vec());
            }
            idx += take;
        }
        Ok(out)
    }

    fn checksum_impl(&mut self, record: &[i32]) -> Result<i64> {
        let fname = artifact_name("checksum", 1, self.n, "i32");
        let n = self.n;
        if record.len() != n {
            return Err(Error::runtime("checksum: wrong record length"));
        }
        let t0 = std::time::Instant::now();
        let exe = self.exe(&fname)?;
        let lit = xla::Literal::vec1(record).reshape(&[1, n as i64])?;
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple1()?;
        let v = tuple.to_vec::<i64>()?;
        self.exec_wall += t0.elapsed();
        self.executions += 1;
        Ok(v[0])
    }
}

impl GoldenBackend for PjrtGolden {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn sort_i32(&mut self, records: &[Vec<i32>], descending: bool) -> Result<Vec<Vec<i32>>> {
        self.sort_impl(records, descending)
    }

    fn checksum(&mut self, record: &[i32]) -> Result<i64> {
        self.checksum_impl(record)
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            executions: self.executions,
            compile_wall: self.compile_wall,
            exec_wall: self.exec_wall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::XorShift64;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn model() -> PjrtGolden {
        PjrtGolden::load(&artifacts_dir(), 1024)
            .expect("artifacts missing — run `make artifacts`")
    }

    #[test]
    fn sort_matches_std() {
        let mut m = model();
        let mut rng = XorShift64::new(11);
        let rec = rng.vec_i32(1024);
        let got = m.sort_i32(&[rec.clone()], false).unwrap();
        let mut expect = rec;
        expect.sort_unstable();
        assert_eq!(got[0], expect);
    }

    #[test]
    fn sort_descending_and_batches() {
        let mut m = model();
        let mut rng = XorShift64::new(12);
        let records: Vec<Vec<i32>> = (0..9).map(|_| rng.vec_i32(1024)).collect();
        let got = m.sort_i32(&records, true).unwrap();
        assert_eq!(got.len(), 9);
        for (g, r) in got.iter().zip(&records) {
            let mut e = r.clone();
            e.sort_unstable();
            e.reverse();
            assert_eq!(g, &e);
        }
        // 9 records with {8,1} artifacts → at least 2 executions.
        assert!(m.executions >= 2);
    }

    #[test]
    fn check_sorted_catches_corruption() {
        let mut m = model();
        let mut rng = XorShift64::new(13);
        let rec = rng.vec_i32(1024);
        let mut sorted = rec.clone();
        sorted.sort_unstable();
        m.check_sorted(&rec, &sorted, false).unwrap();
        sorted[100] ^= 1;
        let err = m.check_sorted(&rec, &sorted, false).unwrap_err();
        assert!(err.to_string().contains("golden mismatch"), "{err}");
    }

    #[test]
    fn checksum_is_order_invariant() {
        let mut m = model();
        let mut rng = XorShift64::new(14);
        let rec = rng.vec_i32(1024);
        let mut shuffled = rec.clone();
        shuffled.reverse();
        assert_eq!(m.checksum(&rec).unwrap(), m.checksum(&shuffled).unwrap());
        let mut other = rec.clone();
        other[5] ^= 3;
        assert_ne!(m.checksum(&rec).unwrap(), m.checksum(&other).unwrap());
    }

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let err = match PjrtGolden::load(Path::new("/nonexistent"), 1024) {
            Err(e) => e,
            Ok(_) => panic!("load should fail"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
