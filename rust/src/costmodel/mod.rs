//! Physical-system cost models.
//!
//! Two things about the paper's evaluation cannot be *measured* here
//! because they require a physical FPGA and the Xilinx toolchain:
//! the FPGA compilation flow times of Table II and the post-P&R
//! resource utilization of §III. Both are reproduced as documented,
//! calibrated models (DESIGN.md §2): [`flow`] reproduces the debug
//! iteration comparison, [`resources`] the LUT/BRAM utilization.

pub mod flow;
pub mod resources;

pub use flow::{FlowModel, IterationBreakdown};
pub use resources::{ResourceModel, Utilization};
