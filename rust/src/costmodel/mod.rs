//! Physical-system cost models.
//!
//! Two things about the paper's evaluation cannot be *measured* here
//! because they require a physical FPGA and the Xilinx toolchain:
//! the FPGA compilation flow times of Table II and the post-P&R
//! resource utilization of §III. Both are reproduced as documented,
//! calibrated models (DESIGN.md §2): [`flow`] reproduces the debug
//! iteration comparison, [`resources`] the LUT/BRAM utilization.
//!
//! Calibration policy: every constant is anchored on a number the
//! paper itself reports (1617 s synthesis, 2672 s place-and-route,
//! 120 s reboot; the platform IP LUT counts of §III) and scaled by
//! the one free variable the model exposes (design size in LUTs, from
//! [`ResourceModel`]). The co-simulation column of Table II is never
//! modeled — it is measured live by `vmhdl flow` and the
//! `table2_debug_iteration` bench, so the headline speedup always
//! reflects this machine, not the paper's.

pub mod flow;
pub mod resources;
pub mod tlpcost;

pub use flow::{FlowModel, IterationBreakdown};
pub use resources::{ResourceModel, Utilization};
pub use tlpcost::{TlpCostModel, TlpWireCost};
