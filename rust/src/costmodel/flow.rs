//! Debug-iteration flow model (Table II).
//!
//! The physical column is a calibrated model: the paper measured the
//! Vivado 2016.2 flow for the sorting platform on a Xeon E5-2620 v3
//! (Table I) — synthesis 1617 s, place & route 2672 s, reboot 120 s,
//! execution 32 µs. Those constants anchor the model; synthesis and
//! P&R scale roughly linearly with utilized LUTs around the reference
//! design's utilization (a standard first-order Vivado runtime model).
//!
//! The co-simulation column is *measured* by the bench harness
//! (elaboration + run of the same workload; HDL "compilation" here is
//! the incremental `cargo build` of the simulator, the analogue of
//! VCS compilation — the paper's 167 s).

use std::time::Duration;

/// One debug-iteration's time breakdown (a row set of Table II).
#[derive(Debug, Clone)]
pub struct IterationBreakdown {
    pub compilation: Option<Duration>,
    pub synthesis: Option<Duration>,
    pub place_route: Option<Duration>,
    pub reboot: Option<Duration>,
    pub execution: Duration,
}

impl IterationBreakdown {
    pub fn total(&self) -> Duration {
        self.compilation.unwrap_or_default()
            + self.synthesis.unwrap_or_default()
            + self.place_route.unwrap_or_default()
            + self.reboot.unwrap_or_default()
            + self.execution
    }
}

/// The calibrated physical-flow model.
#[derive(Debug, Clone)]
pub struct FlowModel {
    /// Reference measurements (paper Table II).
    pub synth_ref: Duration,
    pub pnr_ref: Duration,
    pub reboot: Duration,
    /// LUTs of the reference design the synth/P&R numbers correspond to.
    pub ref_luts: u64,
    /// Fixed flow overhead that does not scale with design size
    /// (project open, netlist IO, bitgen) — folded into the reference
    /// numbers; exposed for ablation.
    pub fixed_fraction: f64,
}

impl FlowModel {
    /// Calibrated to the paper's Table I/II (Vivado 2016.2, SUME,
    /// sorting platform at 11% LUT utilization of the xc7vx690t).
    pub fn paper() -> Self {
        Self {
            synth_ref: Duration::from_secs(1617),
            pnr_ref: Duration::from_secs(2672),
            reboot: Duration::from_secs(120),
            ref_luts: (super::resources::XC7VX690T_LUTS as f64 * 0.11) as u64,
            fixed_fraction: 0.3,
        }
    }

    /// Predicted synthesis time for a design of `luts`.
    pub fn synthesis(&self, luts: u64) -> Duration {
        self.scale(self.synth_ref, luts)
    }

    /// Predicted place-&-route time for a design of `luts`.
    pub fn place_route(&self, luts: u64) -> Duration {
        self.scale(self.pnr_ref, luts)
    }

    fn scale(&self, base: Duration, luts: u64) -> Duration {
        let ratio = luts as f64 / self.ref_luts.max(1) as f64;
        let scaled = base.as_secs_f64() * (self.fixed_fraction + (1.0 - self.fixed_fraction) * ratio);
        Duration::from_secs_f64(scaled)
    }

    /// The physical-system debug iteration for a design of `luts`
    /// whose on-hardware execution takes `execution`.
    pub fn physical_iteration(&self, luts: u64, execution: Duration) -> IterationBreakdown {
        IterationBreakdown {
            compilation: None,
            synthesis: Some(self.synthesis(luts)),
            place_route: Some(self.place_route(luts)),
            reboot: Some(self.reboot),
            execution,
        }
    }

    /// The co-simulation debug iteration from *measured* components.
    pub fn cosim_iteration(compile: Duration, execution: Duration) -> IterationBreakdown {
        IterationBreakdown {
            compilation: Some(compile),
            synthesis: None,
            place_route: None,
            reboot: None,
            execution,
        }
    }
}

/// Render the two iterations as the paper's Table II.
pub fn render_table2(phys: &IterationBreakdown, cosim: &IterationBreakdown) -> String {
    use crate::coordinator::stats::fmt_dur;
    let f = |o: &Option<Duration>| o.map(fmt_dur).unwrap_or_else(|| "-".to_string());
    let mut s = String::new();
    s.push_str("TABLE II — RUN TIME COMPARISON (physical column: calibrated model)\n");
    s.push_str(&format!("{:<18}{:>22}{:>22}\n", "", "Physical System", "Co-Simulation"));
    s.push_str(&format!("{:<18}{:>22}{:>22}\n", "Compilation", f(&phys.compilation), f(&cosim.compilation)));
    s.push_str(&format!("{:<18}{:>22}{:>22}\n", "Synthesis", f(&phys.synthesis), f(&cosim.synthesis)));
    s.push_str(&format!("{:<18}{:>22}{:>22}\n", "Place and Route", f(&phys.place_route), f(&cosim.place_route)));
    s.push_str(&format!("{:<18}{:>22}{:>22}\n", "Reboot", f(&phys.reboot), f(&cosim.reboot)));
    s.push_str(&format!("{:<18}{:>22}{:>22}\n", "Execution", fmt_dur(phys.execution), fmt_dur(cosim.execution)));
    s.push_str(&format!("{:<18}{:>22}{:>22}\n", "Total", fmt_dur(phys.total()), fmt_dur(cosim.total())));
    let speedup = phys.total().as_secs_f64() / cosim.total().as_secs_f64().max(1e-9);
    s.push_str(&format!("Debug-iteration speedup: {speedup:.1}x (paper: ≈25x)\n"));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_totals_reproduce_25x() {
        // With the paper's own co-sim measurements (167 s compile,
        // 6.02 s execute) the model must reproduce Table II's ≈25×.
        let m = FlowModel::paper();
        let phys = m.physical_iteration(m.ref_luts, Duration::from_micros(32));
        let cosim = FlowModel::cosim_iteration(
            Duration::from_secs(167),
            Duration::from_secs_f64(6.02),
        );
        let total_phys = phys.total().as_secs_f64();
        let total_cosim = cosim.total().as_secs_f64();
        assert!((total_phys - 4409.0).abs() < 1.0, "{total_phys}");
        assert!((total_cosim - 173.02).abs() < 0.1, "{total_cosim}");
        let speedup = total_phys / total_cosim;
        assert!((24.0..27.0).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn scaling_is_monotonic_with_fixed_floor() {
        let m = FlowModel::paper();
        let small = m.synthesis(m.ref_luts / 10);
        let ref_t = m.synthesis(m.ref_luts);
        let big = m.synthesis(m.ref_luts * 2);
        assert!(small < ref_t && ref_t < big);
        // Fixed fraction: a tiny design still pays ~30%.
        assert!(small > Duration::from_secs_f64(1617.0 * 0.3));
        assert_eq!(ref_t, Duration::from_secs(1617));
    }

    #[test]
    fn table_renders_all_rows() {
        let m = FlowModel::paper();
        let phys = m.physical_iteration(m.ref_luts, Duration::from_micros(32));
        let cosim = FlowModel::cosim_iteration(
            Duration::from_secs(167),
            Duration::from_secs_f64(6.02),
        );
        let t = render_table2(&phys, &cosim);
        for row in ["Compilation", "Synthesis", "Place and Route", "Reboot", "Execution", "Total", "speedup"] {
            assert!(t.contains(row), "missing {row} in:\n{t}");
        }
    }
}
