//! Per-TLP wire-overhead model for the transaction-layer link mode.
//!
//! The paper's §V argument against forwarding raw PCIe messages is
//! quantified here from the *actual* fragmentation the bridge performs
//! ([`crate::pcie::tlp::fragment_read`] is the same function that
//! splits DMA bursts on the live `LinkMode::Tlp` data path), so the
//! model cannot drift from the implementation: a DMA read of `len`
//! bytes costs one MRd request header per fragment plus one CplD
//! header per fragment, and only the completions carry payload.
//!
//! The headline is Table III's payload sensitivity: large bursts
//! amortise toward the max-payload floor (~4.5 % at a 512 B MPS),
//! while a 64 B burst pays over 25 % in headers — which is why the
//! framework's message-level link mode (one logical message per
//! burst) beats TLP forwarding for small records.

use crate::pcie::tlp::{fragment_read, HDR_3DW_BYTES, HDR_4DW_BYTES};

/// Default max payload size in DWs (512 B — the paper platform's
/// PCIe core configuration).
pub const DEFAULT_MPS_DW: u16 = 128;

/// Wire-byte accounting for one DMA read burst under fragmentation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TlpWireCost {
    /// Number of (request, completion) TLP pairs the burst splits into.
    pub tlps: usize,
    /// Header bytes across requests and completions.
    pub header_bytes: u64,
    /// Payload bytes actually carried (the useful data).
    pub payload_bytes: u64,
}

impl TlpWireCost {
    /// Total bytes on the wire.
    pub fn wire_bytes(&self) -> u64 {
        self.header_bytes + self.payload_bytes
    }

    /// Header overhead as a fraction of wire bytes (0 when empty).
    pub fn overhead_ratio(&self) -> f64 {
        let total = self.wire_bytes();
        if total == 0 {
            return 0.0;
        }
        self.header_bytes as f64 / total as f64
    }
}

/// TLP wire-cost model, parameterised on the link's max payload size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TlpCostModel {
    /// Max payload size per TLP, in DWs.
    pub mps_dw: u16,
}

impl Default for TlpCostModel {
    fn default() -> Self {
        Self { mps_dw: DEFAULT_MPS_DW }
    }
}

impl TlpCostModel {
    pub fn new(mps_dw: u16) -> Self {
        Self { mps_dw: mps_dw.max(1) }
    }

    /// Cost of one DMA read of `len` bytes at `addr`: per fragment,
    /// an MRd request header (3 DW below 4 GiB, 4 DW above) plus a
    /// 3 DW CplD header; payload rides only in the completions.
    pub fn read_burst(&self, addr: u64, len: u32) -> TlpWireCost {
        let req_hdr =
            if addr > u32::MAX as u64 { HDR_4DW_BYTES } else { HDR_3DW_BYTES } as u64;
        let frags = fragment_read(addr, len, self.mps_dw);
        let tlps = frags.len();
        let mut header_bytes = 0u64;
        let mut payload_bytes = 0u64;
        for (_, len_dw) in frags {
            header_bytes += req_hdr + HDR_3DW_BYTES as u64;
            payload_bytes += len_dw as u64 * 4;
        }
        TlpWireCost { tlps, header_bytes, payload_bytes }
    }

    /// Table III payload-sensitivity sweep: `(burst bytes, overhead
    /// ratio)` rows over the bursts the workloads actually issue
    /// (a 64 B descriptor fetch up to a 4 KiB record).
    pub fn table_iii_rows(&self) -> Vec<(u32, f64)> {
        [64u32, 128, 256, 512, 1024, 4096]
            .iter()
            .map(|&len| (len, self.read_burst(0x1000, len).overhead_ratio()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_fragment_small_read() {
        let m = TlpCostModel::new(128);
        let c = m.read_burst(0x1000, 256);
        assert_eq!(c.tlps, 1);
        assert_eq!(c.payload_bytes, 256);
        assert_eq!(c.header_bytes, (HDR_3DW_BYTES * 2) as u64);
        assert!((c.overhead_ratio() - 24.0 / 280.0).abs() < 1e-12);
    }

    #[test]
    fn fragmentation_multiplies_headers_not_payload() {
        let m = TlpCostModel::new(16); // 64 B fragments
        let c = m.read_burst(0x1000, 1024);
        assert_eq!(c.tlps, 16);
        assert_eq!(c.payload_bytes, 1024);
        assert_eq!(c.header_bytes, 16 * (HDR_3DW_BYTES * 2) as u64);
    }

    #[test]
    fn high_addresses_pay_the_4dw_request_header() {
        let m = TlpCostModel::new(128);
        let lo = m.read_burst(0x1000, 512);
        let hi = m.read_burst(0x1_0000_0000, 512);
        assert_eq!(
            hi.header_bytes - lo.header_bytes,
            (HDR_4DW_BYTES - HDR_3DW_BYTES) as u64
        );
    }

    #[test]
    fn overhead_shrinks_with_payload_size() {
        let rows = TlpCostModel::default().table_iii_rows();
        assert!(rows.windows(2).all(|w| w[1].1 <= w[0].1),
            "overhead ratio must be monotone non-increasing in burst size: {rows:?}");
        // Floor = headers per full fragment: 24 / (24 + 512) ≈ 4.5 %.
        let last = rows.last().unwrap();
        assert!(last.1 < 0.05, "4 KiB burst should sit near the MPS floor: {last:?}");
        assert!(rows[0].1 > 0.2, "64 B burst overhead should be substantial");
    }

    #[test]
    fn zero_length_read_costs_nothing() {
        let c = TlpCostModel::default().read_burst(0, 0);
        assert_eq!(c.tlps, 0);
        assert_eq!(c.wire_bytes(), 0);
        assert_eq!(c.overhead_ratio(), 0.0);
    }
}
