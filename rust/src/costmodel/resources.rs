//! FPGA resource model (§III: "The FPGA LUT utilization after place
//! and route is 11%, and the BRAM utilization is 19%").
//!
//! The sorter's contribution is derived structurally from the network
//! (compare-exchange count, per-stage delay buffers at stream width
//! w); the fixed-IP contributions (PCIe core, AXI DMA, interconnect)
//! use the published Xilinx 7-series utilization figures for those
//! cores at the platform's configuration. Calibration anchor: the
//! paper's reference platform must land at ≈11% LUT / ≈19% BRAM of
//! the xc7vx690t.

use crate::hdl::axi::WORDS_PER_BEAT;
use crate::hdl::kernel::KernelKind;
use crate::hdl::sorter;

/// xc7vx690t capacity (Virtex-7, NetFPGA SUME).
pub const XC7VX690T_LUTS: u64 = 433_200;
pub const XC7VX690T_BRAM36: u64 = 1_470;
pub const XC7VX690T_FFS: u64 = 866_400;

/// A block's resource estimate.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Estimate {
    pub luts: u64,
    pub ffs: u64,
    pub bram36: u64,
}

impl std::ops::Add for Estimate {
    type Output = Estimate;
    fn add(self, o: Estimate) -> Estimate {
        Estimate {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            bram36: self.bram36 + o.bram36,
        }
    }
}

/// Utilization as a fraction of the device.
#[derive(Debug, Clone, Copy)]
pub struct Utilization {
    pub lut_pct: f64,
    pub bram_pct: f64,
    pub ff_pct: f64,
}

/// The resource model for the streaming-accelerator platform.
#[derive(Debug, Clone)]
pub struct ResourceModel {
    /// Record length (words) of the stream kernel.
    pub n: usize,
    /// Stream width (words/beat).
    pub w: usize,
    /// Which stream kernel the platform carries between the streams.
    /// The calibration anchor (≈11% LUT / ≈19% BRAM) is the paper's
    /// **sort** platform and must not move; the fold kernels swap in a
    /// far smaller accelerator block.
    pub accel_kernel: KernelKind,
    // Per-primitive costs (7-series, 32-bit datapath):
    /// LUTs per physical compare-exchange (32-bit compare + 2:1 muxes).
    pub luts_per_cas: u64,
    /// LUTs per 32-bit word of shift-register delay (SRL32-based).
    pub luts_per_delay_word: u64,
    /// Words of delay buffering per BRAM36 before the tools map the
    /// delay lines to block RAM instead of SRLs.
    pub srl_to_bram_threshold: u64,
    // Fixed IP blocks (published figures for this configuration):
    pub pcie_core: Estimate,
    pub axi_dma: Estimate,
    /// Include the AXI DMA's scatter-gather engine (descriptor fetch
    /// + writeback datapath, per-channel descriptor state). The
    /// paper's platform is direct-register mode, so the ≈11%/19%
    /// calibration anchor excludes this; `--queue-depth > 1` runs use
    /// the SG-mode estimate.
    pub dma_sg: bool,
    /// PG021-class increment for the SG engine (both channels): an
    /// extra AXI master for descriptor traffic, the fetch/writeback
    /// FSMs, and a descriptor BRAM.
    pub dma_sg_engine: Estimate,
    pub interconnect: Estimate,
    /// Platform glue: resets, clocking, CSRs, stream FIFOs, and the
    /// NetFPGA SUME reference-project infrastructure around the
    /// accelerator (calibration anchor — see module docs).
    pub infrastructure: Estimate,
}

impl Default for ResourceModel {
    fn default() -> Self {
        Self::paper_platform()
    }
}

impl ResourceModel {
    /// The paper's configuration: N=1024, w=4 on the SUME platform.
    pub fn paper_platform() -> Self {
        Self {
            n: 1024,
            w: WORDS_PER_BEAT,
            accel_kernel: KernelKind::Sort,
            luts_per_cas: 96,
            luts_per_delay_word: 8,
            srl_to_bram_threshold: 1024,
            // PCIe Gen3 x8 hard-block wrapper + AXI bridge (Xilinx
            // PG194-class figures).
            pcie_core: Estimate { luts: 18_000, ffs: 24_000, bram36: 36 },
            // AXI DMA v7.1, direct mode, 128-bit (PG021-class).
            axi_dma: Estimate { luts: 2_800, ffs: 3_900, bram36: 6 },
            dma_sg: false,
            dma_sg_engine: Estimate { luts: 1_500, ffs: 2_100, bram36: 2 },
            // AXI interconnect + protocol converters.
            interconnect: Estimate { luts: 3_500, ffs: 4_200, bram36: 0 },
            // SUME reference infrastructure (10G MACs kept in the
            // reference project, microblaze, etc.) + packet buffers —
            // dominates BRAM, as on the real board.
            infrastructure: Estimate { luts: 14_000, ffs: 18_000, bram36: 215 },
        }
    }

    /// Structural estimate of the streaming sorting network.
    pub fn sorter(&self) -> Estimate {
        // A width-w streaming network instantiates w/2 physical CAS
        // per stage (each handles 2 of the w lanes per cycle).
        let stages = sorter::network_stages(self.n).len() as u64;
        let cas = stages * (self.w as u64 / 2);
        let cas_luts = cas * self.luts_per_cas;
        // Delay buffering: each stage (k, j) must hold ~j words per
        // lane-pair to realign partners that are j apart.
        let delay_words: u64 = sorter::network_stages(self.n)
            .iter()
            .map(|&(_, j)| (j as u64).max(self.w as u64))
            .sum();
        let (delay_luts, delay_bram) = if delay_words > self.srl_to_bram_threshold {
            // Large delays map to BRAM36 (1024 × 36b each).
            (0, delay_words.div_ceil(1024))
        } else {
            (delay_words * self.luts_per_delay_word, 0)
        };
        Estimate {
            luts: cas_luts + delay_luts,
            ffs: cas_luts, // one pipeline FF layer per CAS LUT, first order
            bram36: delay_bram,
        }
    }

    /// The platform with the DMA elaborated in SG mode (what a
    /// `--queue-depth > 1` deployment would synthesize).
    pub fn with_sg(mut self) -> Self {
        self.dma_sg = true;
        self
    }

    /// The platform with a different stream kernel behind the streams
    /// (what a `--kernel checksum|stats` device would synthesize).
    pub fn for_kernel(mut self, kind: KernelKind) -> Self {
        self.accel_kernel = kind;
        self
    }

    /// Structural estimate of the **checksum** fold kernel: one 32-bit
    /// adder + xor per lane, a reduction layer, and the 64-bit
    /// accumulator — no delay buffering at all (7-series: a 32-bit
    /// add/xor pair is ~64 LUTs with carry chains; the accumulator and
    /// control add a small constant).
    pub fn checksum_kernel(&self) -> Estimate {
        let lane_luts = self.w as u64 * 64;
        Estimate {
            luts: lane_luts + 160,
            ffs: lane_luts + 96, // pipeline + accumulator registers
            bram36: 0,
        }
    }

    /// Structural estimate of the **stats** fold kernel: per lane a
    /// min comparator, a max comparator and an adder (~96 LUTs), a
    /// reduction layer, and min/max/sum/count accumulators.
    pub fn stats_kernel(&self) -> Estimate {
        let lane_luts = self.w as u64 * 96;
        Estimate {
            luts: lane_luts + 224,
            ffs: lane_luts + 160,
            bram36: 0,
        }
    }

    /// The accelerator block as configured ([`ResourceModel::accel_kernel`]).
    pub fn accelerator(&self) -> Estimate {
        match self.accel_kernel {
            KernelKind::Sort => self.sorter(),
            KernelKind::Checksum => self.checksum_kernel(),
            KernelKind::Stats => self.stats_kernel(),
        }
    }

    /// The DMA block as configured (direct or SG mode).
    pub fn dma(&self) -> Estimate {
        if self.dma_sg {
            self.axi_dma + self.dma_sg_engine
        } else {
            self.axi_dma
        }
    }

    /// Whole-platform estimate.
    pub fn platform(&self) -> Estimate {
        self.accelerator() + self.pcie_core + self.dma() + self.interconnect
            + self.infrastructure
    }

    /// Device utilization of the whole platform.
    pub fn utilization(&self) -> Utilization {
        let e = self.platform();
        Utilization {
            lut_pct: 100.0 * e.luts as f64 / XC7VX690T_LUTS as f64,
            bram_pct: 100.0 * e.bram36 as f64 / XC7VX690T_BRAM36 as f64,
            ff_pct: 100.0 * e.ffs as f64 / XC7VX690T_FFS as f64,
        }
    }

    /// Render the §III utilization report.
    pub fn render(&self) -> String {
        let s = self.accelerator();
        let p = self.platform();
        let u = self.utilization();
        let accel_name = match self.accel_kernel {
            KernelKind::Sort => "sorter (structural)",
            KernelKind::Checksum => "checksum kernel",
            KernelKind::Stats => "stats kernel",
        };
        let mut out = String::new();
        out.push_str("RESOURCE MODEL — xc7vx690t (NetFPGA SUME)\n");
        out.push_str(&format!(
            "{:<22}{:>10}{:>10}{:>10}\n",
            "block", "LUTs", "FFs", "BRAM36"
        ));
        for (name, e) in [
            (accel_name, s),
            ("pcie core", self.pcie_core),
            (
                if self.dma_sg { "axi dma (sg mode)" } else { "axi dma" },
                self.dma(),
            ),
            ("interconnect", self.interconnect),
            ("infrastructure", self.infrastructure),
            ("TOTAL", p),
        ] {
            out.push_str(&format!(
                "{:<22}{:>10}{:>10}{:>10}\n",
                name, e.luts, e.ffs, e.bram36
            ));
        }
        out.push_str(&format!(
            "utilization: {:.1}% LUT, {:.1}% BRAM (paper: 11% LUT, 19% BRAM)\n",
            u.lut_pct, u.bram_pct
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_platform_lands_near_11_and_19_percent() {
        let u = ResourceModel::paper_platform().utilization();
        assert!(
            (9.0..13.0).contains(&u.lut_pct),
            "LUT {:.1}% outside 11%±2",
            u.lut_pct
        );
        assert!(
            (17.0..21.0).contains(&u.bram_pct),
            "BRAM {:.1}% outside 19%±2",
            u.bram_pct
        );
    }

    #[test]
    fn sorter_scales_with_n() {
        // Note n=256 can show *more* LUTs than n=1024: below the
        // SRL→BRAM threshold the delay lines burn LUTs instead of
        // BRAM (a real 7-series effect). Compare well below and above.
        let mut small = ResourceModel::paper_platform();
        small.n = 64;
        let big = ResourceModel::paper_platform();
        assert!(small.sorter().luts < big.sorter().luts);
        assert!(small.sorter().bram36 <= big.sorter().bram36);
        let mut huge = ResourceModel::paper_platform();
        huge.n = 4096;
        assert!(huge.sorter().luts > big.sorter().luts);
        assert!(huge.sorter().bram36 > big.sorter().bram36);
    }

    #[test]
    fn render_contains_totals() {
        let r = ResourceModel::paper_platform().render();
        assert!(r.contains("TOTAL"));
        assert!(r.contains("utilization"));
    }

    #[test]
    fn fold_kernels_are_small_and_leave_the_anchor_unmoved() {
        // Swapping the accelerator must not disturb the paper's
        // ≈11%/19% calibration: the sort platform is untouched...
        let sort = ResourceModel::paper_platform();
        assert_eq!(sort.accel_kernel, KernelKind::Sort);
        assert_eq!(sort.accelerator(), sort.sorter());
        let u = sort.utilization();
        assert!((9.0..13.0).contains(&u.lut_pct));
        // ...and the fold kernels are orders of magnitude smaller than
        // the sorting network (a handful of adders/comparators vs 55
        // stages of compare-exchange + delay lines).
        for kind in [KernelKind::Checksum, KernelKind::Stats] {
            let m = ResourceModel::paper_platform().for_kernel(kind);
            let a = m.accelerator();
            assert!(a.luts > 0 && a.ffs > 0);
            assert!(
                a.luts * 10 < sort.sorter().luts,
                "{kind} kernel implausibly large: {} LUTs",
                a.luts
            );
            assert_eq!(a.bram36, 0, "a streaming fold needs no BRAM");
            // Fixed IP blocks dominate such a platform.
            assert!(m.platform().luts < sort.platform().luts);
            assert!(m.utilization().lut_pct < u.lut_pct);
        }
        // Stats carries more comparators than checksum.
        let c = ResourceModel::paper_platform().checksum_kernel();
        let s = ResourceModel::paper_platform().stats_kernel();
        assert!(s.luts > c.luts);
        // Render names the swapped block.
        let r = ResourceModel::paper_platform()
            .for_kernel(KernelKind::Checksum)
            .render();
        assert!(r.contains("checksum kernel"), "{r}");
    }

    #[test]
    fn sg_mode_adds_dma_resources_without_moving_the_anchor() {
        // The ≈11%/19% calibration anchor is the paper's direct-mode
        // platform; SG mode (descriptor rings, `--queue-depth > 1`)
        // costs a bounded increment on top.
        let direct = ResourceModel::paper_platform();
        let sg = ResourceModel::paper_platform().with_sg();
        assert_eq!(direct.platform(), direct.sorter() + direct.pcie_core
            + direct.axi_dma + direct.interconnect + direct.infrastructure);
        let d_luts = sg.platform().luts - direct.platform().luts;
        assert_eq!(d_luts, sg.dma_sg_engine.luts);
        assert!(sg.platform().bram36 > direct.platform().bram36);
        // Still a small fraction of the device (< 1% LUT delta).
        assert!(
            sg.utilization().lut_pct - direct.utilization().lut_pct < 1.0,
            "SG engine increment implausibly large"
        );
        assert!(sg.render().contains("sg mode"));
    }
}
