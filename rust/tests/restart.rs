//! Independent-restart integration tests (paper §II): either side of
//! the co-simulation restarts without affecting the other, over the
//! same four-unidirectional-channel UDS topology the paper uses.

use std::time::Duration;

use vmhdl::coordinator::cosim::{CoSim, CoSimCfg, TransportKind};
use vmhdl::coordinator::lifecycle::HdlThread;
use vmhdl::testutil::XorShift64;
use vmhdl::vm::guest::SortDriver;
use vmhdl::vm::vmm::{GuestEnv, NoopHook};

fn uds_cfg(tag: &str) -> (CoSimCfg, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "vmhdl-it-restart-{tag}-{}",
        std::process::id()
    ));
    let cfg = CoSimCfg {
        transport: TransportKind::Uds(dir.clone()),
        ..CoSimCfg::default()
    };
    (cfg, dir)
}

fn sort_one(env: &mut GuestEnv, drv: &mut SortDriver, rng: &mut XorShift64) {
    let rec = rng.vec_i32(1024);
    let out = drv.sort_record(env, &rec).unwrap();
    let mut e = rec;
    e.sort_unstable();
    assert_eq!(out, e);
}

#[test]
fn hdl_restart_vm_survives() {
    let (cfg, dir) = uds_cfg("h");
    let mut hdl = HdlThread::spawn(&dir, cfg.clone()).unwrap();
    let mut cosim = CoSim::launch(cfg).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.timeout = Duration::from_secs(30);
    drv.probe(&mut env).unwrap();
    let mut rng = XorShift64::new(1);
    sort_one(&mut env, &mut drv, &mut rng);

    // Kill + restart the simulator; VM-side state fully survives.
    hdl.kill().unwrap();
    hdl.restart().unwrap();
    drv.probe(&mut env).unwrap(); // driver re-initializes the "rebooted" FPGA
    sort_one(&mut env, &mut drv, &mut rng);
    sort_one(&mut env, &mut drv, &mut rng);

    let rep = hdl.stop().unwrap();
    assert_eq!(rep.records_done, 2, "post-restart incarnation sorted 2");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn vm_restart_hdl_survives() {
    let (cfg, dir) = uds_cfg("v");
    let hdl = HdlThread::spawn(&dir, cfg.clone()).unwrap();
    {
        let mut cosim = CoSim::launch(cfg.clone()).unwrap();
        let mut hook = NoopHook;
        let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
        let mut drv = SortDriver::new(1024);
        drv.timeout = Duration::from_secs(30);
        drv.probe(&mut env).unwrap();
        let mut rng = XorShift64::new(2);
        sort_one(&mut env, &mut drv, &mut rng);
    } // VM incarnation 1 dies
    {
        let mut cosim = CoSim::launch(cfg).unwrap();
        let mut hook = NoopHook;
        let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
        let mut drv = SortDriver::new(1024);
        drv.timeout = Duration::from_secs(30);
        drv.probe(&mut env).unwrap();
        let mut rng = XorShift64::new(3);
        sort_one(&mut env, &mut drv, &mut rng);
    }
    let rep = hdl.stop().unwrap();
    assert_eq!(rep.records_done, 2, "one record per VM incarnation");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hdl_killed_mid_wait_yields_timeout_not_crash() {
    let (mut cfg, dir) = uds_cfg("m");
    cfg.vcd = None;
    let mut hdl = HdlThread::spawn(&dir, cfg.clone()).unwrap();
    let mut cosim = CoSim::launch(cfg).unwrap();
    cosim.vmm.dev_mut().mmio_timeout = Duration::from_millis(800);
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.timeout = Duration::from_secs(30);
    drv.probe(&mut env).unwrap();

    // Kill the HDL side, then try an MMIO read: the VM must get a
    // clean timeout error (the paper's "device hung" experience),
    // not a crash or deadlock.
    hdl.kill().unwrap();
    let err = env.read32(0, 0x08).unwrap_err();
    assert!(err.to_string().contains("timeout"), "{err}");

    // Restart: the same VM continues without being recreated.
    hdl.restart().unwrap();
    drv.probe(&mut env).unwrap();
    let mut rng = XorShift64::new(4);
    sort_one(&mut env, &mut drv, &mut rng);
    hdl.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rapid_restart_storm_converges() {
    // Several restarts in a row must never wedge the link layer.
    let (cfg, dir) = uds_cfg("s");
    let mut hdl = HdlThread::spawn(&dir, cfg.clone()).unwrap();
    let mut cosim = CoSim::launch(cfg).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.timeout = Duration::from_secs(30);
    let mut rng = XorShift64::new(5);
    for _ in 0..3 {
        hdl.restart().unwrap();
        drv.probe(&mut env).unwrap();
        sort_one(&mut env, &mut drv, &mut rng);
    }
    hdl.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
