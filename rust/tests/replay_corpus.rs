//! Replay regression corpus: every spec under
//! `tests/corpus/recordings/` names a scenario that must stay
//! replayable. With a committed `run.vhrec` next to the spec the test
//! replays that exact log (a regression gate on cycle accounting and
//! the wire format); without one it records the scenario fresh and
//! replays its own log — so plain `cargo test -q` needs nothing but
//! the specs. See the corpus `README.md` for the re-record protocol.

use std::path::{Path, PathBuf};

use vmhdl::coordinator::cosim::CoSimCfg;
use vmhdl::coordinator::replay::replay_dir;
use vmhdl::coordinator::scenario::{self, ShardPolicy};
use vmhdl::hdl::kernel::KernelKind;
use vmhdl::link::recorder::REC_FILE;
use vmhdl::link::ImpairCfg;

#[derive(Debug)]
struct Spec {
    name: String,
    devices: usize,
    records: usize,
    seed: u64,
    depth: usize,
    n: usize,
    kernels: Vec<(usize, KernelKind)>,
    device_n: Vec<(usize, usize)>,
    impair: Option<ImpairCfg>,
}

fn corpus_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus/recordings")
}

fn num(v: &str) -> u64 {
    let v = v.trim();
    let parsed = match v.strip_prefix("0x") {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => v.parse(),
    };
    parsed.unwrap_or_else(|e| panic!("bad number {v:?} in spec: {e}"))
}

fn parse_spec(path: &Path) -> Spec {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let mut spec = Spec {
        name: String::new(),
        devices: 1,
        records: 3,
        seed: 1,
        depth: 1,
        n: 256,
        kernels: Vec::new(),
        device_n: Vec::new(),
        impair: None,
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .unwrap_or_else(|| panic!("{}: not a key = value line: {line:?}", path.display()));
        let (key, value) = (key.trim(), value.trim());
        match key.split_once('.') {
            Some(("kernel", k)) => {
                let kind: KernelKind = value
                    .parse()
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                spec.kernels.push((num(k) as usize, kind));
            }
            Some(("device_n", k)) => {
                spec.device_n.push((num(k) as usize, num(value) as usize));
            }
            Some(("impair", field)) => {
                let ic = spec.impair.get_or_insert_with(ImpairCfg::default);
                match field {
                    "drop_ppm" => ic.drop_ppm = num(value) as u32,
                    "dup_ppm" => ic.dup_ppm = num(value) as u32,
                    "reorder_ppm" => ic.reorder_ppm = num(value) as u32,
                    "corrupt_ppm" => ic.corrupt_ppm = num(value) as u32,
                    "seed" => ic.seed = num(value),
                    other => panic!("{}: unknown impair field {other:?}", path.display()),
                }
            }
            _ => match key {
                "name" => spec.name = value.to_string(),
                "devices" => spec.devices = num(value) as usize,
                "records" => spec.records = num(value) as usize,
                "seed" => spec.seed = num(value),
                "depth" => spec.depth = num(value) as usize,
                "n" => spec.n = num(value) as usize,
                other => panic!("{}: unknown key {other:?}", path.display()),
            },
        }
    }
    assert!(!spec.name.is_empty(), "{}: spec has no name", path.display());
    spec
}

fn spec_paths() -> Vec<PathBuf> {
    let mut specs: Vec<PathBuf> = std::fs::read_dir(corpus_dir())
        .expect("corpus directory missing")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "spec"))
        .collect();
    specs.sort();
    specs
}

/// Run the spec's scenario live with `--record` pointed at `dir`.
fn record(spec: &Spec, dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
    let mut cfg = CoSimCfg {
        devices: spec.devices,
        ..Default::default()
    };
    cfg.platform.kernel.n = spec.n;
    cfg.device_kernel = spec.kernels.clone();
    cfg.device_n = spec.device_n.clone();
    cfg.impair = spec.impair;
    cfg.seed = spec.seed;
    cfg.record = Some(dir.to_path_buf());
    scenario::run_sharded_offload_depth(
        cfg,
        spec.records,
        spec.seed,
        ShardPolicy::RoundRobin,
        spec.depth,
        None,
    )
    .unwrap_or_else(|e| panic!("{}: recording run failed: {e}", spec.name));
}

#[test]
fn corpus_recordings_replay_bit_exactly() {
    let rerecord = std::env::var("VMHDL_CORPUS_RERECORD").is_ok_and(|v| v == "1");
    for path in spec_paths() {
        let spec = parse_spec(&path);
        let committed = corpus_dir().join(&spec.name);
        let (dir, scratch) = if committed.join(REC_FILE).exists() && !rerecord {
            (committed, false)
        } else if rerecord {
            record(&spec, &committed);
            (committed, false)
        } else {
            let dir = std::env::temp_dir()
                .join(format!("vhcorpus-{}-{}", spec.name, std::process::id()));
            record(&spec, &dir);
            (dir, true)
        };
        let rep = replay_dir(&dir, None)
            .unwrap_or_else(|e| panic!("{}: replay diverged: {e}", spec.name));
        assert_eq!(rep.devices, spec.devices, "{}", spec.name);
        assert!(!rep.partial, "{}: clean run must carry a trailer", spec.name);
        assert_eq!(
            rep.per_device_records.iter().sum::<u64>(),
            spec.records as u64,
            "{}",
            spec.name
        );
        assert!(
            rep.compared > 0,
            "{}: no device→guest payload frames compared",
            spec.name
        );
        if scratch {
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn corpus_covers_the_acceptance_matrix() {
    let specs: Vec<Spec> = spec_paths().iter().map(|p| parse_spec(p)).collect();
    assert_eq!(specs.len(), 3, "corpus must hold exactly the three acceptance specs");
    assert!(
        specs
            .iter()
            .any(|s| s.devices == 1 && s.kernels.is_empty() && s.impair.is_none()),
        "clean single-device sort spec missing"
    );
    assert!(
        specs
            .iter()
            .any(|s| s.devices == 3 && s.depth == 2 && s.kernels.len() == 2),
        "mixed-fleet depth-2 spec missing"
    );
    assert!(
        specs.iter().any(|s| {
            s.impair
                .as_ref()
                .is_some_and(|i| i.drop_ppm == 50_000)
        }),
        "impaired drop=0.05 spec missing"
    );
}
