//! Fuzzing the recording codec: truncated, corrupted, version-bumped
//! and random logs must yield structured errors — never a panic, and
//! never a silently-wrong decode (DESIGN.md §4: seeded [`ByteMutator`]
//! is the offline stand-in for a coverage-guided fuzzer). The last
//! test closes the loop at the replay layer: a corrupted payload byte
//! inside an otherwise well-formed log must surface as a divergence
//! report, not a silent pass.

use vmhdl::coordinator::cosim::CoSimCfg;
use vmhdl::coordinator::replay::replay_recording;
use vmhdl::coordinator::scenario;
use vmhdl::link::recorder::{
    decode_recording, encode_frame, encode_header, encode_trailer, read_recording,
    DeviceFinal, DeviceMeta, Dir, RecordMeta, REC_MAGIC, REC_VERSION,
};
use vmhdl::testutil::ByteMutator;

/// A well-formed two-device log with traffic on both channels of both
/// devices and a trailer — every structural feature the format has.
fn baseline() -> Vec<u8> {
    let meta = RecordMeta {
        seed: 7,
        scenario: "fuzz baseline".into(),
        git: "0000000".into(),
        impair: String::new(),
        devices: (0..2)
            .map(|k| DeviceMeta {
                kernel: "sort".into(),
                n: 64,
                latency: 100,
                pipeline_records: 8,
                link_mode: "mmio".into(),
                bram_size: 65536,
                stream_fifo_depth: 64,
                poll_interval: 1,
                device_index: k,
                impair: String::new(),
                fault: if k == 0 { "ur-status@rec=2".into() } else { String::new() },
            })
            .collect(),
    };
    let mut b = encode_header(&meta);
    encode_frame(Dir::GuestToDevice, 0, 0, b"\x10\x20\x30", &mut b);
    encode_frame(Dir::DeviceToGuest, 0, 0, b"\x01\x02\x03\x04\x05", &mut b);
    encode_frame(Dir::GuestToDevice, 1, 1, b"", &mut b);
    encode_frame(Dir::DeviceToGuest, 1, 1, &[0xAA; 64], &mut b);
    encode_trailer(
        &[
            DeviceFinal { cycles: 123, records_done: 1 },
            DeviceFinal { cycles: 456, records_done: 2 },
        ],
        &mut b,
    );
    b
}

#[test]
fn every_truncation_is_a_structured_error() {
    let b = baseline();
    assert!(decode_recording(&b, false).is_ok(), "baseline must decode");
    for cut in 0..b.len() {
        let strict = decode_recording(&b[..cut], false);
        assert!(
            strict.is_err(),
            "cut at {cut}/{}: a truncated log must not decode strictly",
            b.len()
        );
        // Partial mode may recover an event-aligned prefix (that is
        // its job) — it just must never panic or claim completeness.
        if let Ok(rec) = decode_recording(&b[..cut], true) {
            assert!(rec.partial, "cut at {cut}: short log decoded as complete");
            assert!(rec.trailer.is_none(), "cut at {cut}: trailer from thin air");
        }
    }
}

#[test]
fn mutated_logs_never_panic_and_never_decode_nonsense() {
    let base = baseline();
    let mut m = ByteMutator::new(0xF0DD_F0DD);
    for case in 0..2000 {
        let mut buf = base.clone();
        m.mutate(&mut buf);
        for allow_partial in [false, true] {
            // A mutation can land in an opaque payload and leave the
            // log valid — fine. What must hold: no panic, and every
            // successful decode satisfies the format's invariants.
            if let Ok(rec) = decode_recording(&buf, allow_partial) {
                let ndev = rec.meta.devices.len();
                assert!(ndev >= 1, "case {case}: decoded zero devices");
                for ev in &rec.events {
                    assert!(
                        (ev.device as usize) < ndev,
                        "case {case}: event names device {} of {ndev}",
                        ev.device
                    );
                    assert!(ev.chan <= 1, "case {case}: channel {}", ev.chan);
                }
                if let Some(t) = &rec.trailer {
                    assert_eq!(t.len(), ndev, "case {case}: trailer width");
                }
            }
        }
    }
}

#[test]
fn random_garbage_is_rejected() {
    let mut m = ByteMutator::new(0xBAD_5EED);
    for case in 0..2000 {
        let buf = m.random_frame(512);
        let r = decode_recording(&buf, true);
        if buf.len() < REC_MAGIC.len() || buf[..4] != REC_MAGIC {
            assert!(r.is_err(), "case {case}: garbage without magic decoded");
        }
    }
}

#[test]
fn future_version_is_rejected_in_both_modes() {
    let mut b = baseline();
    b[4] = REC_VERSION as u8 + 1;
    for allow_partial in [false, true] {
        let err = decode_recording(&b, allow_partial).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }
}

#[test]
fn corrupted_payload_byte_is_divergence_not_silence() {
    // Record a real single-device run, flip one byte inside the
    // largest device→guest payload frame (the S2MM result data),
    // re-encode the log, and replay: the corruption must be reported
    // as a divergence with the event index — never a silent pass.
    let dir = std::env::temp_dir().join(format!("vhfuzz-replay-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = CoSimCfg::default();
    cfg.platform.kernel.n = 64;
    cfg.record = Some(dir.clone());
    cfg.seed = 0x5EED;
    scenario::run_sort_offload(cfg, 1, 0x5EED, None).unwrap();
    let rec = read_recording(&dir, false).unwrap();
    let _ = std::fs::remove_dir_all(&dir);

    let victim = rec
        .events
        .iter()
        .enumerate()
        .filter(|(_, e)| e.dir == Dir::DeviceToGuest)
        .max_by_key(|(_, e)| e.bytes.len())
        .map(|(i, _)| i)
        .expect("run produced no device→guest frames");
    let mut events = rec.events.clone();
    let last = events[victim].bytes.len() - 1;
    events[victim].bytes[last] ^= 0x01;

    let mut b = encode_header(&rec.meta);
    for e in &events {
        encode_frame(e.dir, e.device, e.chan, &e.bytes, &mut b);
    }
    encode_trailer(rec.trailer.as_deref().expect("clean run has a trailer"), &mut b);
    let corrupted = decode_recording(&b, false).expect("re-encoded log must decode");
    let err = replay_recording(&corrupted, None)
        .expect_err("corrupted payload replayed without complaint");
    assert!(err.to_string().contains("divergence"), "{err}");
}
