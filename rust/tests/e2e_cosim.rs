//! End-to-end integration tests: full co-simulation (VM side + HDL
//! side) across link modes, completion modes and workloads, with
//! results golden-checked against a [`GoldenBackend`] — the native
//! reference by default, the AOT XLA executables under
//! `--features pjrt`.

use std::time::Duration;

use vmhdl::coordinator::cosim::{CoSim, CoSimCfg};
use vmhdl::coordinator::scenario;
use vmhdl::link::LinkMode;
use vmhdl::runtime::{GoldenBackend, NativeGolden};
use vmhdl::testutil::XorShift64;
use vmhdl::vm::guest::{app, CompletionMode, SortDriver};
use vmhdl::vm::vmm::{GuestEnv, NoopHook};

#[test]
fn offload_with_golden_check() {
    let mut golden = NativeGolden::new(1024).unwrap();
    let rep = scenario::run_sort_offload(
        CoSimCfg::default(),
        3,
        0x60D,
        Some(&mut golden),
    )
    .unwrap();
    assert!(rep.golden_checked);
    assert_eq!(rep.records, 3);
    assert_eq!(rep.hdl.records_done, 3);
    // Warm-up + 3 checks all went through the backend.
    assert!(golden.stats().executions >= 4);
}

#[cfg(feature = "pjrt")]
#[test]
fn offload_with_pjrt_golden_check() {
    // Same flow through the PJRT backend: RTL output must match the
    // AOT XLA executables too (closing the RTL == artifact == kernel
    // loop). Needs `make artifacts`.
    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut golden = vmhdl::runtime::PjrtGolden::load(&artifacts, 1024)
        .expect("run `make artifacts` first");
    let rep = scenario::run_sort_offload(
        CoSimCfg::default(),
        2,
        0x60E,
        Some(&mut golden),
    )
    .unwrap();
    assert!(rep.golden_checked);
    assert_eq!(rep.hdl.records_done, 2);
}

#[test]
fn offload_in_tlp_mode() {
    let cfg = CoSimCfg {
        mode: LinkMode::Tlp,
        platform: vmhdl::hdl::platform::PlatformCfg {
            link_mode: LinkMode::Tlp,
            ..Default::default()
        },
        ..Default::default()
    };
    let rep = scenario::run_sort_offload(cfg, 2, 0x117, None).unwrap();
    assert_eq!(rep.records, 2);
    // TLP framing costs more wire bytes than the high-level messages.
    assert!(rep.link_bytes > 0);
}

#[test]
fn poll_mode_driver_completes_without_interrupts() {
    let mut cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.mode = CompletionMode::Poll;
    drv.timeout = Duration::from_secs(30);
    drv.probe(&mut env).unwrap();
    let mut rng = XorShift64::new(5);
    let rec = rng.vec_i32(1024);
    let out = drv.sort_record(&mut env, &rec).unwrap();
    let mut e = rec;
    e.sort_unstable();
    assert_eq!(out, e);
    assert_eq!(drv.stats.irqs_taken, 0, "poll mode must not consume irqs");
    assert!(drv.stats.polls > 0);
    cosim.shutdown().unwrap();
}

#[test]
fn descending_order_via_control_register() {
    let mut cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.timeout = Duration::from_secs(30);
    drv.probe(&mut env).unwrap();
    drv.set_descending(&mut env, true).unwrap();
    let mut rng = XorShift64::new(6);
    let rec = rng.vec_i32(1024);
    let out = drv.sort_record(&mut env, &rec).unwrap();
    let mut e = rec;
    e.sort_unstable();
    e.reverse();
    assert_eq!(out, e);
    // Back to ascending.
    drv.set_descending(&mut env, false).unwrap();
    let rec2 = rng.vec_i32(1024);
    let out2 = drv.sort_record(&mut env, &rec2).unwrap();
    let mut e2 = rec2;
    e2.sort_unstable();
    assert_eq!(out2, e2);
    cosim.shutdown().unwrap();
}

#[test]
fn bad_length_fault_surfaces_as_dma_error() {
    let mut cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.faults.bad_length = true;
    drv.timeout = Duration::from_secs(5);
    drv.probe(&mut env).unwrap();
    let mut rng = XorShift64::new(7);
    let rec = rng.vec_i32(1024);
    let err = drv.sort_record(&mut env, &rec).unwrap_err();
    let s = err.to_string();
    assert!(
        s.contains("error") || s.contains("DMASR") || s.contains("Err"),
        "unexpected failure mode: {s}"
    );
    cosim.shutdown().unwrap();
}

#[test]
fn skip_irq_ack_breaks_the_second_offload_only() {
    // The classic "works once" driver bug: a missed W1C leaves the
    // level high, so the next completion has no rising edge → no MSI.
    let mut cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.faults.skip_irq_ack = true;
    drv.timeout = Duration::from_secs(2);
    drv.probe(&mut env).unwrap();
    let mut rng = XorShift64::new(8);
    let r1 = rng.vec_i32(1024);
    assert!(drv.sort_record(&mut env, &r1).is_ok(), "first offload should work");
    drv.state = vmhdl::vm::guest::DriverState::Complete;
    let r2 = rng.vec_i32(1024);
    let err = drv.sort_record(&mut env, &r2).unwrap_err();
    assert!(err.to_string().contains("never arrived"), "{err}");
    cosim.shutdown().unwrap();
}

#[test]
fn irq_self_test_roundtrip() {
    let mut cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.timeout = Duration::from_secs(30);
    drv.probe(&mut env).unwrap();
    for _ in 0..5 {
        let lat = drv.irq_self_test(&mut env).unwrap();
        assert!(lat < Duration::from_secs(5));
    }
    cosim.shutdown().unwrap();
}

#[test]
fn bram_bulk_window_consistency() {
    let mut cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let mut hook = NoopHook;
    let mut env = GuestEnv::new(&mut cosim.vmm, &mut hook);
    let mut drv = SortDriver::new(1024);
    drv.timeout = Duration::from_secs(30);
    drv.probe(&mut env).unwrap();
    app::run_bram_stress(&mut env, 128, 0xB4A).unwrap();
    cosim.shutdown().unwrap();
}

#[test]
fn many_records_back_to_back() {
    let rep = scenario::run_sort_offload(CoSimCfg::default(), 8, 0xBB, None).unwrap();
    assert_eq!(rep.hdl.records_done, 8);
    // Device time per record must stay in the paper's regime (a few
    // thousand cycles each, not millions).
    let per_record = rep.device_cycles / 8;
    assert!(per_record > 1256, "per-record {per_record} impossibly fast");
    assert!(per_record < 100_000, "per-record {per_record} far too slow");
}
