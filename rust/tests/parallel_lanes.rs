//! Worker-pool determinism: the `--lane-threads` knob changes *wall
//! clock only*. For a fixed seed, per-device cycle counts and
//! delivered outputs must be byte-identical at T = 1 (the
//! merged-horizon pick loop), T = 2 and T = 4 (the
//! [`vmhdl::coordinator::lanepool`] worker pool) — the tentpole's
//! hard requirement, and the plain-`cargo test` counterpart of the
//! loom models in `loom_lanepool.rs`.

use vmhdl::coordinator::cosim::CoSimCfg;
use vmhdl::coordinator::scenario::{self, ShardPolicy, ShardedReport};

/// Small-n fleet (4× smaller records than the paper platform — fast
/// e2e cases, same control paths), pinned to `threads` lane workers.
fn fleet_cfg(devices: usize, threads: usize) -> CoSimCfg {
    let mut cfg = CoSimCfg { devices, lane_threads: threads, ..Default::default() };
    cfg.platform.kernel.n = 64;
    cfg
}

fn run(devices: usize, threads: usize, seed: u64) -> (ShardedReport, Vec<Vec<i32>>) {
    scenario::run_sharded_offload_depth(
        fleet_cfg(devices, threads),
        8,
        seed,
        ShardPolicy::RoundRobin,
        2,
        None,
    )
    .unwrap()
}

#[test]
fn per_device_cycles_identical_across_worker_counts() {
    let seed = 0x1A9E_5EED;
    let (rep1, out1) = run(4, 1, seed);
    for threads in [2usize, 4] {
        let (rep, out) = run(4, threads, seed);
        assert_eq!(
            rep.per_device_cycles, rep1.per_device_cycles,
            "T={threads} shifted device cycles vs T=1"
        );
        assert_eq!(
            rep.per_device_records, rep1.per_device_records,
            "T={threads} changed record routing vs T=1"
        );
        assert_eq!(out, out1, "T={threads} changed delivered bytes vs T=1");
    }
}

#[test]
fn pool_reports_sane_wall_split_per_lane() {
    // Busy wall is measured inside each lane; idle is derived from
    // the pool's total. Neither may exceed the run wall, and every
    // lane must have actually parked at least once (idle accounting
    // keeps the Tables II/III dual-clock split meaningful under the
    // pool).
    let (rep, _) = run(4, 4, 0xACC7);
    for (k, hdl) in rep.hdl.iter().enumerate() {
        assert!(
            hdl.wall_busy <= hdl.wall,
            "device {k}: busy {:?} exceeds wall {:?}",
            hdl.wall_busy,
            hdl.wall
        );
        assert!(hdl.idle_waits > 0, "device {k} was never serviced to idle");
    }
}
