//! Record→replay round-trip properties: any live co-simulation run,
//! recorded with `--record`, must replay offline (`vmhdl replay`) to
//! the exact per-device cycle counts and device→guest byte stream —
//! across device counts, kernel mixes, queue depths, link impairment
//! and policies. Plus the checkpoint-fork and snapshot identity laws
//! the replay driver builds on.

use std::path::PathBuf;

use vmhdl::coordinator::cosim::CoSimCfg;
use vmhdl::coordinator::replay::replay_dir;
use vmhdl::coordinator::scenario::{self, ShardPolicy};
use vmhdl::hdl::kernel::KernelKind;
use vmhdl::hdl::platform::{Platform, PlatformCfg};
use vmhdl::hdl::sim::{ForceMap, TickCtx};
use vmhdl::link::recorder::{read_recording, Dir};
use vmhdl::link::{Endpoint, ImpairCfg, LinkMode, Msg};
use vmhdl::testutil::XorShift64;

/// Fresh scratch directory for one recording (removed by the caller).
fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("vhrr-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Draw a random co-sim configuration from `rng`: 1–3 devices, mixed
/// kernels on some fleets, depth 1–2, sometimes an impaired link —
/// the same knobs the CLI exposes, so the property covers what users
/// can actually record.
fn random_cfg(rng: &mut XorShift64) -> (CoSimCfg, usize, ShardPolicy, usize) {
    let devices = 1 + rng.below(3) as usize;
    let depth = 1 + rng.below(2) as usize;
    let records = 2 + rng.below(4) as usize;
    let mut cfg = CoSimCfg { devices, ..Default::default() };
    cfg.platform.kernel.n = if rng.below(2) == 0 { 64 } else { 256 };
    if devices >= 2 && rng.below(2) == 0 {
        cfg.device_kernel.push((1, KernelKind::Checksum));
    }
    if devices == 3 && rng.below(2) == 0 {
        cfg.device_kernel.push((2, KernelKind::Stats));
        cfg.device_n.push((2, 64));
    }
    if rng.below(3) == 0 {
        cfg.impair = Some(ImpairCfg {
            drop_ppm: 20_000,
            dup_ppm: 10_000,
            seed: rng.next_u64(),
            ..Default::default()
        });
    }
    // Work-steal schedules are timing-dependent across runs — but the
    // recording captures the one schedule that actually happened, so
    // even those runs must replay exactly.
    let policy = if rng.below(4) == 0 {
        ShardPolicy::WorkSteal
    } else {
        ShardPolicy::RoundRobin
    };
    cfg.seed = rng.next_u64();
    (cfg, records, policy, depth)
}

#[test]
fn record_replay_roundtrip_over_random_configs() {
    let mut rng = XorShift64::new(0x5EED_0FF1);
    for case in 0..20 {
        let (mut cfg, records, policy, depth) = random_cfg(&mut rng);
        let dir = tmp_dir(&format!("case{case}"));
        cfg.record = Some(dir.clone());
        let seed = cfg.seed;
        let impaired = cfg.impair.is_some();
        let (live, _outs) =
            scenario::run_sharded_offload_depth(cfg, records, seed, policy, depth, None)
                .unwrap_or_else(|e| panic!("case {case}: live run failed: {e}"));
        let rep = replay_dir(&dir, None).unwrap_or_else(|e| {
            panic!("case {case} ({policy} depth {depth} impaired={impaired}): {e}")
        });
        assert!(!rep.partial, "case {case}: clean run must record a trailer");
        assert_eq!(rep.devices, live.devices, "case {case}");
        // The trailer check inside `replay_recording` already enforced
        // this bit-exactly; re-assert against the live report so a
        // trailer-writing bug can't vacuously pass.
        let live_cycles: Vec<u64> = live.hdl.iter().map(|h| h.cycles).collect();
        let live_records: Vec<u64> = live.hdl.iter().map(|h| h.records_done).collect();
        assert_eq!(rep.per_device_cycles, live_cycles, "case {case}");
        assert_eq!(rep.per_device_records, live_records, "case {case}");
        assert!(
            rep.compared > 0,
            "case {case}: replay compared no device→guest payload frames"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn replay_can_fork_from_a_mid_run_checkpoint() {
    let dir = tmp_dir("ckpt");
    let mut cfg = CoSimCfg::default();
    cfg.platform.kernel.n = 256;
    cfg.record = Some(dir.clone());
    cfg.seed = 0xC0FFEE;
    let live = scenario::run_sort_offload(cfg, 2, 0xC0FFEE, None).unwrap();
    let rec = read_recording(&dir, false).unwrap();
    let injectable = rec
        .events
        .iter()
        .filter(|e| e.dir == Dir::GuestToDevice)
        .count();
    assert!(injectable > 2, "run too short to fork mid-way");
    // Fork through snapshot()/restore() half-way: the restored copy
    // must finish the walk with the same cycles and bytes.
    let rep = replay_dir(&dir, Some(injectable / 2)).unwrap();
    assert!(rep.checkpoint_forked);
    assert_eq!(rep.per_device_cycles, vec![live.hdl.cycles]);
    assert_eq!(rep.per_device_records, vec![live.hdl.records_done]);
    // A checkpoint beyond the end of the log is an error, not a no-op.
    let err = replay_dir(&dir, Some(injectable + 1)).unwrap_err();
    assert!(err.to_string().contains("checkpoint"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replay_of_the_same_recording_is_deterministic() {
    let dir = tmp_dir("det");
    let mut cfg = CoSimCfg { devices: 2, ..Default::default() };
    cfg.platform.kernel.n = 64;
    cfg.record = Some(dir.clone());
    cfg.seed = 0xD5;
    let _ = scenario::run_sharded_offload_depth(
        cfg,
        4,
        0xD5,
        ShardPolicy::RoundRobin,
        2,
        None,
    )
    .unwrap();
    let a = replay_dir(&dir, None).unwrap();
    let b = replay_dir(&dir, None).unwrap();
    assert_eq!(a.per_device_cycles, b.per_device_cycles);
    assert_eq!(a.compared, b.compared);
    assert_eq!(a.injected, b.injected);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_restore_snapshot_identity_across_geometries() {
    // snapshot(); restore(); snapshot() must be byte-identical for
    // every kernel kind × link mode the replay driver can rebuild.
    let forces = ForceMap::new();
    for kind in [KernelKind::Sort, KernelKind::Checksum, KernelKind::Stats] {
        for mode in [LinkMode::Mmio, LinkMode::Tlp] {
            let mut pcfg = PlatformCfg {
                link_mode: mode,
                ..Default::default()
            };
            pcfg.kernel.kind = kind;
            pcfg.kernel.n = 64;
            let (mut vm_ep, mut hdl_ep) = Endpoint::inproc_pair();
            let mut plat = Platform::new(pcfg.clone());
            if mode == LinkMode::Mmio {
                // Put a write in flight so the snapshot carries real
                // mid-pipeline state, not just reset values.
                vm_ep
                    .send(&Msg::MmioWrite { bar: 0, addr: 0x08, data: vec![9, 0, 0, 0] })
                    .unwrap();
            }
            for cycle in 0..5u64 {
                let ctx = TickCtx { cycle, forces: &forces };
                plat.tick(&ctx, &mut hdl_ep).unwrap();
            }
            let snap = plat.snapshot(5);
            let mut fresh = Platform::new(pcfg);
            assert_eq!(fresh.restore(&snap).unwrap(), 5, "{kind} {mode:?}");
            assert_eq!(
                fresh.snapshot(5),
                snap,
                "{kind} {mode:?}: snapshot();restore();snapshot() diverged"
            );
        }
    }
}
