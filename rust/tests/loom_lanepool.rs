//! Loom models of the lane-pool handoff: the
//! [`vmhdl::hdl::sim::LaneReadyQueue`] ↔ [`vmhdl::link::Doorbell`]
//! protocol that `coordinator/lanepool.rs` builds its workers on.
//!
//! Two claims get exhaustive interleaving coverage here (the plain
//! determinism test lives in `parallel_lanes.rs`):
//!
//! 1. **No lost wakeup at the release seam.** A frame that lands
//!    while its lane's worker is releasing must end queued: the
//!    releaser publishes `IDLE` *before* its final rx re-check, the
//!    transport stores the frame *before* ringing, and a parker
//!    samples the epoch *before* scanning. Loom drives the producer's
//!    store+ring through every point of both consumers' sequences; a
//!    stranded frame shows up as a loom deadlock (parker blocks
//!    forever) or as the final pop assert failing.
//!
//! 2. **No double service.** Two workers racing to wake the same lane
//!    (doorbell scan vs releasing worker) enqueue it exactly once —
//!    the `IDLE → QUEUED` CAS admits one winner, so one `pop` claims
//!    the lane and the next finds the deque empty.
//!
//! Same build plumbing as `loom_doorbell.rs`: this file only compiles
//! under `RUSTFLAGS="--cfg loom"`; the non-blocking CI `loom` job adds
//! the loom crate transiently and runs
//! `cargo test -p vmhdl --release --test loom_lanepool`. Plain
//! `cargo test` compiles this to an empty crate.

#![cfg(loom)]

use std::time::Duration;

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

use vmhdl::hdl::sim::LaneReadyQueue;
use vmhdl::link::Doorbell;

const TICK: Duration = Duration::from_millis(1);

/// The release seam, three-way: a worker releasing lane 0 (IDLE store
/// → rx re-check → CAS-wake + ring), the transport delivering a frame
/// (store → ring), and a parker (epoch sample → scan → conditional
/// wait). Whatever the interleaving, the frame's lane must end up
/// queued exactly once.
#[test]
fn frame_during_release_is_never_stranded() {
    loom::model(|| {
        let queue = Arc::new(LaneReadyQueue::new(1));
        let bell = Doorbell::new();
        // Stands in for `Endpoint::rx_ready()`: 1 ⇒ a frame is
        // buffered. The transport stores it before ringing, exactly
        // like `InProcTransport::send`.
        let rx = Arc::new(AtomicUsize::new(0));

        // Lane 0 starts claimed, as if a worker is servicing it.
        queue.enqueue_all();
        assert_eq!(queue.pop(), Some(0));

        let releaser = {
            let (queue, bell, rx) = (queue.clone(), bell.clone(), rx.clone());
            thread::spawn(move || {
                // service_lane's tail: publish IDLE first, then the
                // final rx re-check, then wake + ring on traffic.
                queue.release(0);
                if rx.load(Ordering::SeqCst) == 1 && queue.wake(0) {
                    bell.ring();
                }
            })
        };
        let producer = {
            let bell = bell.clone();
            let rx = rx.clone();
            thread::spawn(move || {
                rx.store(1, Ordering::SeqCst);
                bell.ring();
            })
        };

        // Parker (worker_loop's idle path): epoch before scan, wait
        // only if the scan found nothing actionable.
        loop {
            let seen = bell.epoch();
            if rx.load(Ordering::SeqCst) == 1 {
                if queue.wake(0) {
                    break; // this scan won the wake
                }
                if !queue.is_idle(0) {
                    break; // queued by the releaser, or still claimed
                           // — its release re-check covers the frame
                }
            }
            bell.wait(seen, TICK);
        }

        releaser.join().expect("releaser panicked");
        producer.join().expect("producer panicked");

        // Exactly one wake won: the frame's lane is queued once, and
        // only once.
        assert_eq!(queue.pop(), Some(0), "frame stranded: lane never queued");
        assert_eq!(queue.pop(), None, "lane queued twice");
    });
}

/// Two workers racing `wake(0)` then `pop()` on a two-lane queue: the
/// CAS admits exactly one winner, exactly one pop claims lane 0, and
/// lane 1's state is untouched by the race.
#[test]
fn racing_wakes_enqueue_exactly_once() {
    loom::model(|| {
        let queue = Arc::new(LaneReadyQueue::new(2));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let queue = queue.clone();
                thread::spawn(move || (queue.wake(0), queue.pop()))
            })
            .collect();
        let results: Vec<(bool, Option<usize>)> =
            workers.into_iter().map(|w| w.join().expect("worker panicked")).collect();

        let wake_wins = results.iter().filter(|(won, _)| *won).count();
        assert_eq!(wake_wins, 1, "CAS admitted {wake_wins} wake winners");
        let claims: Vec<usize> = results.iter().filter_map(|(_, p)| *p).collect();
        assert_eq!(claims, vec![0], "lane 0 claimed {} times", claims.len());
        assert!(queue.is_idle(1), "the race leaked into lane 1's state");
        assert_eq!(queue.pop(), None);
    });
}
