//! End-to-end lossy-link resilience: a full sharded co-simulation
//! (2 devices, queue depth 2) must produce byte-identical results over
//! an impaired link, stay cycle-deterministic across same-seed
//! impaired runs, and survive the UDP transport with faults injected
//! on top. A total one-direction blackhole must *not* hang: it fails
//! loudly, with every device's link health attached to the error
//! (the DEBUGGING.md §9 walkthrough).

use std::time::Duration;

use vmhdl::coordinator::cosim::{CoSimCfg, TransportKind};
use vmhdl::coordinator::scenario::{self, ShardPolicy};
use vmhdl::link::ImpairCfg;

/// Small-n fleet config (4× smaller records than the paper platform —
/// fast e2e cases, same control paths).
fn small_cfg(devices: usize) -> CoSimCfg {
    let mut cfg = CoSimCfg { devices, ..Default::default() };
    cfg.platform.kernel.n = 256;
    cfg
}

fn impaired(mut cfg: CoSimCfg, spec: &str) -> CoSimCfg {
    cfg.impair = Some(ImpairCfg::parse(spec).unwrap());
    cfg
}

/// Moderate loss: every fault kind engaged, none overwhelming — the
/// hang detector must never fire at this level.
const MODERATE: &str = "drop=0.05,dup=0.02,reorder=0.05,corrupt=0.02,seed=42";

#[test]
fn impaired_sharded_run_matches_clean_run_byte_identically() {
    let (records, seed) = (6, 0x1055_1E57);
    let (clean_rep, clean) = scenario::run_sharded_offload_depth(
        small_cfg(2),
        records,
        seed,
        ShardPolicy::RoundRobin,
        2,
        None,
    )
    .unwrap();
    let (rep, outs) = scenario::run_sharded_offload_depth(
        impaired(small_cfg(2), MODERATE),
        records,
        seed,
        ShardPolicy::RoundRobin,
        2,
        None,
    )
    .unwrap();
    assert_eq!(outs, clean, "impairment leaked into delivered results");
    assert_eq!(rep.per_device_records, clean_rep.per_device_records);
    // The reliability layer demonstrably did work: the fault schedule
    // is a pure function of (seed, send index), so at these rates the
    // counters cannot all be zero.
    let healed: u64 = rep
        .hdl
        .iter()
        .map(|h| h.retransmits + h.dups_dropped + h.reorders_healed + h.corrupt_dropped)
        .sum();
    assert!(healed > 0, "impaired run healed nothing — faults never engaged");
}

#[test]
fn impaired_same_seed_runs_are_deterministic() {
    let run = || {
        scenario::run_sharded_offload_depth(
            impaired(small_cfg(2), MODERATE),
            6,
            0xD373_4311,
            ShardPolicy::RoundRobin,
            2,
            None,
        )
        .unwrap()
    };
    let (a, outs_a) = run();
    let (b, outs_b) = run();
    assert_eq!(outs_a, outs_b, "same-seed impaired runs diverged in results");
    assert_eq!(
        a.per_device_cycles, b.per_device_cycles,
        "same-seed impaired runs diverged in per-device cycles"
    );
}

#[test]
fn udp_impaired_sharded_run_delivers_clean_results() {
    let (records, seed) = (4, 0x0DB1_7E57);
    let (_, clean) = scenario::run_sharded_offload_depth(
        small_cfg(2),
        records,
        seed,
        ShardPolicy::RoundRobin,
        2,
        None,
    )
    .unwrap();
    // Real loopback datagrams (OS-assigned ports) with seeded faults
    // injected on top of the UDP sockets.
    let mut cfg = impaired(small_cfg(2), "drop=0.03,reorder=0.03,seed=7");
    cfg.transport = TransportKind::Udp { port: 0, hdl_in_proc: true };
    let (_, outs) =
        scenario::run_sharded_offload_depth(cfg, records, seed, ShardPolicy::RoundRobin, 2, None)
            .unwrap();
    assert_eq!(outs, clean, "UDP + impairment leaked into delivered results");
}

#[test]
fn blackhole_fails_loudly_with_link_health_context() {
    // 100% loss HDL→VM: requests arrive, nothing ever comes back. The
    // run must end in an error (not a hang) whose message carries the
    // link-health snapshot and points at the debugging walkthrough.
    let cfg = impaired(CoSimCfg::default(), "drop=1.0,dir=down,seed=3");
    let err = scenario::run_sort_offload_with_timeout(cfg, 1, 7, None, Duration::from_secs(2))
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("link health"), "no link health in: {msg}");
    assert!(msg.contains("DEBUGGING.md §9"), "no walkthrough pointer in: {msg}");
    assert!(msg.contains("backlog="), "no backlog counter in: {msg}");
}
