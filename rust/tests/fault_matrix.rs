//! The PR 9 fault matrix: every injectable PCIe fault class, end to
//! end through the full co-simulation, must either **recover
//! byte-identically** (the scenario runner golden-checks every
//! completed record, so a `Recovered` outcome implies a correct
//! result) or **fail loudly** with a structured reason naming the
//! device and the latched state — and must never hang. On top of
//! that: same seed + same plan is deterministic, and a recorded fault
//! run replays bit-identically (`vmhdl replay`).

use std::time::Duration;

use vmhdl::coordinator::cosim::CoSimCfg;
use vmhdl::coordinator::replay::replay_dir;
use vmhdl::coordinator::scenario::{
    self, FleetHealth, RecordOutcome, ShardPolicy,
};
use vmhdl::link::recorder::read_recording;
use vmhdl::pcie::FaultPlan;
use vmhdl::Error;

const TIMEOUT: Duration = Duration::from_secs(5);

fn cfg_with_fault(spec: &str) -> CoSimCfg {
    let mut cfg = CoSimCfg::default();
    cfg.platform.kernel.n = 64;
    cfg.device_fault = vec![(0, FaultPlan::parse(spec).unwrap())];
    cfg
}

fn run(spec: &str, records: usize, seed: u64) -> scenario::ScenarioReport {
    scenario::run_sort_offload_with_timeout(cfg_with_fault(spec), records, seed, None, TIMEOUT)
        .unwrap()
}

#[test]
fn completion_timeout_recovers_byte_identically() {
    let rep = run("completion-timeout@rec=2", 4, 0xFA01);
    assert_eq!(rep.outcomes.len(), 4);
    // Record 1 (the 2nd DMA read) lost its completion; the watchdog
    // reset + retry must complete it — and the runner verified the
    // retried result against the reference sort.
    match &rep.outcomes[1] {
        RecordOutcome::Recovered { retries } => assert!(*retries >= 1),
        o => panic!("expected recovered, got {o}"),
    }
    for (i, o) in rep.outcomes.iter().enumerate() {
        if i != 1 {
            assert_eq!(*o, RecordOutcome::Ok, "record {i}: {o}");
        }
    }
    let h = rep.health();
    assert_eq!((h.ok, h.recovered, h.failed), (3, 1, 0));
    assert!(h.lost_devices.is_empty());
    assert!(rep.device_cycles > 0);
}

#[test]
fn two_completion_timeouts_recover_sequentially() {
    // Multi-plan lists: both plans live on one device and each fires
    // on its own non-posted index. The retry for the first timeout
    // shifts the later indices by +1 (retry = its own non-posted
    // request), so `@rec=2` hits record 1 and `@rec=4` hits record 2:
    // req 1 = record 0, req 2 = record 1 (fires, retry = req 3),
    // req 4 = record 2 (fires, retry = req 5), req 6 = record 3.
    let mut cfg = CoSimCfg::default();
    cfg.platform.kernel.n = 64;
    cfg.device_fault = FaultPlan::parse_list("completion-timeout@rec=2,completion-timeout@rec=4")
        .unwrap()
        .into_iter()
        .map(|p| (0usize, p))
        .collect();
    let rep =
        scenario::run_sort_offload_with_timeout(cfg, 4, 0xFA11, None, TIMEOUT).unwrap();
    assert_eq!(rep.outcomes.len(), 4);
    for i in [1usize, 2] {
        match &rep.outcomes[i] {
            RecordOutcome::Recovered { retries } => assert!(*retries >= 1),
            o => panic!("record {i}: expected recovered, got {o}"),
        }
    }
    for i in [0usize, 3] {
        assert_eq!(rep.outcomes[i], RecordOutcome::Ok, "record {i}");
    }
    let h = rep.health();
    assert_eq!((h.ok, h.recovered, h.failed), (2, 2, 0));
    assert!(h.lost_devices.is_empty());
}

#[test]
fn poisoned_cpl_quarantines_and_continues() {
    let rep = run("poisoned-cpl@rec=1", 3, 0xFA02);
    match &rep.outcomes[0] {
        RecordOutcome::Failed { reason } => {
            assert!(reason.contains("device 0"), "reason must name the device: {reason}");
            assert!(
                reason.contains("DMASR"),
                "reason must carry the latched registers: {reason}"
            );
        }
        o => panic!("expected failed, got {o}"),
    }
    // The slot was recycled: the remaining records complete cleanly.
    assert_eq!(rep.outcomes[1], RecordOutcome::Ok);
    assert_eq!(rep.outcomes[2], RecordOutcome::Ok);
    assert_eq!(rep.health().failed, 1);
    assert!(rep.lost_devices.is_empty());
}

#[test]
fn ur_status_quarantines_like_poison() {
    let rep = run("ur-status@rec=2", 3, 0xFA03);
    assert_eq!(rep.outcomes[0], RecordOutcome::Ok);
    assert!(
        matches!(&rep.outcomes[1], RecordOutcome::Failed { reason } if reason.contains("device 0")),
        "{:?}",
        rep.outcomes[1]
    );
    assert_eq!(rep.outcomes[2], RecordOutcome::Ok);
}

#[test]
fn surprise_down_fails_fast_and_marks_the_device_lost() {
    let t0 = std::time::Instant::now();
    let rep = run("surprise-down@rec=2", 4, 0xFA04);
    assert_eq!(rep.outcomes[0], RecordOutcome::Ok);
    assert!(
        matches!(&rep.outcomes[1], RecordOutcome::Failed { reason } if reason.contains("link dead")),
        "{:?}",
        rep.outcomes[1]
    );
    // Remaining records fail fast instead of timing out one by one.
    for o in &rep.outcomes[2..] {
        assert!(matches!(o, RecordOutcome::Failed { .. }), "{o}");
    }
    assert_eq!(rep.lost_devices, vec![0]);
    assert_eq!(rep.device_cycles, 0, "a dead link must not report cycles");
    assert!(!rep.health().all_ok());
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "surprise-down took {:?} — the matrix must never hang",
        t0.elapsed()
    );
}

#[test]
fn reset_inflight_resubmits_exactly_once() {
    let rep = run("reset-inflight@rec=2", 3, 0xFA05);
    assert_eq!(rep.outcomes[0], RecordOutcome::Ok);
    // The scenario reset the device with record 1 in flight; the
    // driver rebuilt and resubmitted it exactly once (verified result,
    // counted as one recovery).
    assert_eq!(rep.outcomes[1], RecordOutcome::Recovered { retries: 1 });
    assert_eq!(rep.outcomes[2], RecordOutcome::Ok);
    assert_eq!(rep.health().recovered, 1);
}

#[test]
fn credit_starve_stalls_but_completes_clean() {
    let rep = run("credit-starve@rec=1", 3, 0xFA06);
    // The bridge-side credit freeze stalls the data path without
    // corrupting it; at worst the watchdog retries a record.
    assert_eq!(rep.health().failed, 0, "{:?}", rep.outcomes);
    assert!(rep.lost_devices.is_empty());
}

#[test]
fn same_seed_same_plan_is_deterministic() {
    for spec in ["completion-timeout@rec=2", "poisoned-cpl@rec=2", "ur-status@rec=1"] {
        let a = run(spec, 3, 0xD5EED);
        let b = run(spec, 3, 0xD5EED);
        assert_eq!(a.outcomes, b.outcomes, "{spec}: outcomes diverged");
        assert_eq!(
            a.device_cycles, b.device_cycles,
            "{spec}: device cycles diverged"
        );
        assert_eq!(a.hdl.records_done, b.hdl.records_done, "{spec}");
    }
}

#[test]
fn sharded_fleet_mixes_fault_classes_per_device() {
    let mut cfg = CoSimCfg::default();
    cfg.platform.kernel.n = 64;
    cfg.devices = 2;
    cfg.device_fault = vec![
        (0, FaultPlan::parse("completion-timeout@rec=1").unwrap()),
        (1, FaultPlan::parse("poisoned-cpl@rec=2").unwrap()),
    ];
    let (rep, outs) =
        scenario::run_sharded_offload_depth(cfg, 6, 0xFA07, ShardPolicy::RoundRobin, 1, None)
            .unwrap();
    let h = rep.health();
    assert_eq!(h.recovered, 1, "dev0's dropped completion retries: {:?}", rep.outcomes);
    assert_eq!(h.failed, 1, "dev1's poisoned record quarantines: {:?}", rep.outcomes);
    assert_eq!(h.ok, 4);
    assert!(h.lost_devices.is_empty());
    // Completed records merged in submission order, sorted (the
    // runner verified them; spot-check the merge is intact).
    assert_eq!(outs.len(), 6);
    for (i, (o, out)) in rep.outcomes.iter().zip(&outs).enumerate() {
        match o {
            RecordOutcome::Failed { .. } => {
                assert!(out.is_empty(), "failed record {i} has a placeholder")
            }
            _ => assert!(out.windows(2).all(|w| w[0] <= w[1]), "record {i} unsorted"),
        }
    }
    assert_eq!(FleetHealth::from_outcomes(&rep.outcomes, vec![]).ok, 4);
}

#[test]
fn non_direct_runners_reject_device_faults_up_front() {
    let mut cfg = CoSimCfg::default();
    cfg.platform.kernel.n = 64;
    cfg.devices = 2;
    cfg.device_fault = vec![(0, FaultPlan::parse("completion-timeout@rec=1").unwrap())];
    let err = scenario::run_sharded_offload_depth(cfg, 4, 1, ShardPolicy::RoundRobin, 2, None)
        .unwrap_err();
    assert!(matches!(err, Error::Config(_)), "{err}");
    assert!(err.to_string().contains("direct runner"), "{err}");
}

#[test]
fn fault_run_records_and_replays_bit_identically() {
    let dir =
        std::env::temp_dir().join(format!("vmhdl-faultrec-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = cfg_with_fault("completion-timeout@rec=2");
    cfg.record = Some(dir.clone());
    cfg.seed = 0xFA08;
    let rep = scenario::run_sort_offload_with_timeout(cfg, 3, 0xFA08, None, TIMEOUT).unwrap();
    assert_eq!(rep.health().recovered, 1);

    // The recording header carries the armed plan (v2 format) …
    let rec = read_recording(&dir, false).unwrap();
    assert_eq!(rec.meta.devices[0].fault, "completion-timeout@rec=2");

    // … and the VM-less replay reproduces the device→guest byte
    // stream of the faulted run exactly, watchdog reset included.
    let rr = replay_dir(&dir, None).unwrap();
    assert!(rr.compared > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
