//! Loom model of the `link::Doorbell` epoch/condvar protocol.
//!
//! The doorbell's correctness claim (transport.rs): *sample the epoch,
//! check for data, then wait only while the epoch is unchanged — a
//! ring between the check and the wait is never lost.* That is a
//! textbook lost-wakeup shape, so it gets a model checker, not just
//! unit tests: loom explores every interleaving of the consumer's
//! check-then-wait against producer rings and fails on any execution
//! where the consumer blocks forever (lost wakeup ⇒ loom deadlock).
//!
//! This file only compiles under `RUSTFLAGS="--cfg loom"`; the
//! non-blocking CI `loom` job adds the loom crate transiently
//! (`cargo add loom@0.7 --target 'cfg(loom)'`) and runs
//! `cargo test -p vmhdl --release --test loom_doorbell`. Plain
//! `cargo test` compiles this to an empty crate — the offline build
//! never needs the dependency.
//!
//! Under loom, `Doorbell::wait` is the untimed variant (loom cannot
//! model timeouts); the epoch protocol under test is identical to the
//! timed production build.

#![cfg(loom)]

use std::time::Duration;

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::thread;

use vmhdl::link::Doorbell;

const TICK: Duration = Duration::from_millis(1);

/// The headline race: the producer rings in the window between the
/// consumer sampling the epoch and the consumer deciding to wait.
/// The epoch comparison inside `wait` must make that ring visible —
/// if it were lost, the consumer would block forever and loom would
/// report a deadlock.
#[test]
fn ring_between_check_and_wait_is_not_lost() {
    loom::model(|| {
        let bell = Doorbell::new();
        let data = loom::sync::Arc::new(AtomicUsize::new(0));

        let producer = {
            let bell = bell.clone();
            let data = data.clone();
            thread::spawn(move || {
                data.store(1, Ordering::SeqCst);
                bell.ring();
            })
        };

        // Consumer: epoch-sample → data-check → conditional wait.
        // Loom schedules the producer's store+ring at every possible
        // point in that sequence.
        let seen = bell.epoch();
        if data.load(Ordering::SeqCst) == 0 {
            bell.wait(seen, TICK);
        }
        assert_eq!(
            data.load(Ordering::SeqCst),
            1,
            "wait returned before the producer's write became visible"
        );

        producer.join().expect("producer panicked");
    });
}

/// Two producers ringing concurrently: every ring bumps the epoch
/// under the same mutex, so the consumer's re-check loop must observe
/// both items without ever blocking past the final ring.
#[test]
fn concurrent_producers_all_observed() {
    loom::model(|| {
        let bell = Doorbell::new();
        let count = loom::sync::Arc::new(AtomicUsize::new(0));

        let p1 = {
            let (bell, count) = (bell.clone(), count.clone());
            thread::spawn(move || {
                count.fetch_add(1, Ordering::SeqCst);
                bell.ring();
            })
        };
        let p2 = {
            let (bell, count) = (bell.clone(), count.clone());
            thread::spawn(move || {
                count.fetch_add(1, Ordering::SeqCst);
                bell.ring();
            })
        };

        loop {
            let seen = bell.epoch();
            if count.load(Ordering::SeqCst) == 2 {
                break;
            }
            bell.wait(seen, TICK);
        }

        p1.join().expect("producer 1 panicked");
        p2.join().expect("producer 2 panicked");
    });
}
