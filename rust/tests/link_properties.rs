//! Property: over an impaired link, the reliable channel delivers
//! every payload exactly once, in order, in both directions — for any
//! seeded fault mix of drops, duplicates, reorders and corruption.
//!
//! The regression corpus (`tests/corpus/impair_regressions.txt`) runs
//! first: specs distilled from past failures plus each fault in
//! isolation. Then a random sweep of ~20 `(seed, drop, dup, reorder,
//! corrupt)` configs derived from one suite seed; every case prints
//! its exact spec on failure, ready to be pasted into the corpus.

use vmhdl::link::{Endpoint, ImpairCfg, ImpairDir, Msg};
use vmhdl::testutil::XorShift64;

const CORPUS: &str = include_str!("corpus/impair_regressions.txt");

/// Random sweep size on top of the corpus.
const RANDOM_CASES: u64 = 20;

/// Drive `n` payloads each way across an impaired in-proc pair and
/// assert exactly-once, in-order delivery on both sides.
fn check_exactly_once(cfg: &ImpairCfg, label: &str) {
    let n = 120u64;
    let (mut vm, mut hdl) = Endpoint::inproc_pair();
    vm.impair(cfg);
    hdl.impair(cfg);
    for i in 0..n {
        vm.send(&Msg::MmioWrite { bar: 0, addr: i, data: vec![i as u8] })
            .unwrap();
        hdl.send(&Msg::Interrupt { vector: i as u16 }).unwrap();
    }
    let mut down = Vec::new(); // delivered at HDL
    let mut up = Vec::new(); // delivered at VM
    let mut rounds = 0u32;
    while (down.len() as u64) < n || (up.len() as u64) < n {
        hdl.poll_into(&mut down).unwrap();
        vm.poll_into(&mut up).unwrap();
        vm.nudge_retransmit();
        hdl.nudge_retransmit();
        rounds += 1;
        assert!(
            rounds < 200_000,
            "{label}: link never converged ({} down, {} up of {n})",
            down.len(),
            up.len()
        );
    }
    for (i, m) in down.iter().enumerate() {
        match m {
            Msg::MmioWrite { addr, .. } => {
                assert_eq!(*addr, i as u64, "{label}: VM→HDL out of order at {i}")
            }
            other => panic!("{label}: unexpected VM→HDL delivery {other:?}"),
        }
    }
    for (i, m) in up.iter().enumerate() {
        match m {
            Msg::Interrupt { vector } => {
                assert_eq!(*vector, i as u16, "{label}: HDL→VM out of order at {i}")
            }
            other => panic!("{label}: unexpected HDL→VM delivery {other:?}"),
        }
    }
    // Exactly-once: nothing extra trickles out afterwards.
    assert_eq!(hdl.poll().unwrap().len(), 0, "{label}: extra VM→HDL deliveries");
    assert_eq!(vm.poll().unwrap().len(), 0, "{label}: extra HDL→VM deliveries");
}

#[test]
fn prop_corpus_configs_deliver_exactly_once_in_order() {
    let mut ran = 0;
    for line in CORPUS.lines() {
        let spec = line.trim();
        if spec.is_empty() || spec.starts_with('#') {
            continue;
        }
        let cfg = ImpairCfg::parse(spec)
            .unwrap_or_else(|e| panic!("corpus line {spec:?} failed to parse: {e}"));
        check_exactly_once(&cfg, &format!("corpus[{spec}]"));
        ran += 1;
    }
    assert!(ran >= 8, "corpus unexpectedly small: {ran} configs");
}

#[test]
fn prop_random_impairments_deliver_exactly_once_in_order() {
    let mut rng = XorShift64::new(0x11A7_4B0B_5EED_0001);
    for case in 0..RANDOM_CASES {
        let cfg = ImpairCfg {
            drop_ppm: rng.below(300_001) as u32,
            dup_ppm: rng.below(150_001) as u32,
            reorder_ppm: rng.below(300_001) as u32,
            corrupt_ppm: rng.below(100_001) as u32,
            jitter_us: 0,
            seed: rng.next_u64(),
            dir: ImpairDir::Both,
        };
        let label = format!(
            "random case {case}: drop={},dup={},reorder={},corrupt={},seed={:#x} (ppm)",
            cfg.drop_ppm, cfg.dup_ppm, cfg.reorder_ppm, cfg.corrupt_ppm, cfg.seed
        );
        check_exactly_once(&cfg, &label);
    }
}
