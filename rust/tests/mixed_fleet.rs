//! End-to-end tests of the heterogeneous stream-kernel fleet: N PCIe
//! devices carrying different compute cores (sort / checksum / stats)
//! and different record lengths on one simulated topology, driven
//! concurrently by the sharded runners — the acceptance surface of
//! the pluggable [`vmhdl::hdl::kernel::StreamKernel`] layer.

use std::time::Duration;

use vmhdl::coordinator::cosim::{CoSim, CoSimCfg};
use vmhdl::coordinator::scenario::{self, device_specs, DeviceSpec, ShardPolicy};
use vmhdl::hdl::kernel::{pack_checksum_words, pack_stats_words, KernelKind};
use vmhdl::pcie::board;
use vmhdl::pcie::config_space::regs as cfg_regs;
use vmhdl::runtime::native::{record_checksum, record_stats};
use vmhdl::testutil::XorShift64;
use vmhdl::vm::guest::{SortDriver, SortDriverSg};
use vmhdl::vm::vmm::{GuestEnv, NoopHook};

/// The acceptance fleet: device 0 sorts 256-word records, device 1
/// checksums 256-word records, device 2 computes stats over 64-word
/// records (a per-device `n` override on top of the kernel override).
fn mixed_cfg() -> CoSimCfg {
    let mut cfg = CoSimCfg { devices: 3, ..Default::default() };
    cfg.platform.kernel.n = 256;
    cfg.device_kernel = vec![(1, KernelKind::Checksum), (2, KernelKind::Stats)];
    cfg.device_n = vec![(2, 64)];
    cfg
}

/// Expected outputs for `records` drawn from `seed` against the fleet
/// of `specs`, reproducing the runner's routing (record i → group
/// i mod G, groups in device order) and the matching golden op.
fn expected_outputs(specs: &[DeviceSpec], records: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut groups: Vec<DeviceSpec> = Vec::new();
    for s in specs {
        if !groups.contains(s) {
            groups.push(*s);
        }
    }
    let mut rng = XorShift64::new(seed);
    (0..records)
        .map(|i| {
            let g = groups[i % groups.len()];
            let input = rng.vec_i32(g.n);
            match g.kernel {
                KernelKind::Sort => {
                    let mut e = input;
                    e.sort_unstable();
                    e
                }
                KernelKind::Checksum => pack_checksum_words(record_checksum(&input)).to_vec(),
                KernelKind::Stats => {
                    let s = record_stats(&input);
                    pack_stats_words(s.min, s.max, s.sum, s.count).to_vec()
                }
            }
        })
        .collect()
}

#[test]
fn mixed_fleet_static_and_work_steal_match_golden_ops() {
    // The acceptance criterion: a 3-device sort+checksum+stats run
    // (static and work-steal) completes with every record's result
    // equal to the matching GoldenBackend op, per-device n honored.
    let records = 9;
    let seed = 0x3F1EE7;
    let specs = device_specs(&mixed_cfg());
    assert_eq!(
        specs,
        vec![
            DeviceSpec { kernel: KernelKind::Sort, n: 256 },
            DeviceSpec { kernel: KernelKind::Checksum, n: 256 },
            DeviceSpec { kernel: KernelKind::Stats, n: 64 },
        ]
    );
    let expect = expected_outputs(&specs, records, seed);
    for policy in [ShardPolicy::RoundRobin, ShardPolicy::WorkSteal] {
        for depth in [1usize, 2] {
            let (rep, outs) = scenario::run_sharded_offload_depth(
                mixed_cfg(),
                records,
                seed,
                policy,
                depth,
                None,
            )
            .unwrap_or_else(|e| panic!("{policy} depth {depth}: {e}"));
            assert_eq!(outs, expect, "{policy} depth {depth}: outputs diverged");
            assert_eq!(rep.records, records);
            assert_eq!(rep.per_device_records.iter().sum::<usize>(), records);
            // Sort results are 256 words, checksum 4, stats 8 — the
            // probed completion size drove every S2MM transfer.
            assert_eq!(outs[0].len(), 256);
            assert_eq!(outs[1].len(), 4);
            assert_eq!(outs[2].len(), 8);
            // Every device did real, accounted work.
            assert!(rep.per_device_cycles.iter().all(|&c| c > 0));
            assert_eq!(rep.hdl.len(), 3);
            assert_eq!(
                rep.hdl.iter().map(|h| h.records_done).sum::<u64>(),
                records as u64
            );
        }
    }
}

#[test]
fn mixed_fleet_same_seed_runs_are_cycle_deterministic_at_depth2() {
    // The determinism contract survives heterogeneity: under a static
    // policy the fill→drain→ack discipline lands every control MMIO
    // on a quiesced device, whatever kernel it carries.
    let run = || {
        scenario::run_sharded_offload_depth(
            mixed_cfg(),
            6,
            0xD37A11,
            ShardPolicy::RoundRobin,
            2,
            None,
        )
        .unwrap()
    };
    let (a, outs_a) = run();
    let (b, outs_b) = run();
    assert_eq!(
        a.per_device_cycles, b.per_device_cycles,
        "mixed-fleet per-device cycles must not depend on host timing"
    );
    assert_eq!(outs_a, outs_b);
    assert_eq!(a.per_device_records, b.per_device_records);
    // Depth 2 ran the SG rings on every device.
    for (k, h) in a.hdl.iter().enumerate() {
        assert!(h.desc_fetches > 0, "device {k} never fetched a descriptor");
        assert_eq!(h.desc_fetches, h.desc_writebacks, "device {k} ring leaked");
    }
}

#[test]
fn wrong_kernel_probe_is_refused_with_diagnosis() {
    // DEBUGGING.md §6: a driver that requires a sorter must refuse a
    // checksum device at probe time — before any record is staged.
    let mut cosim = CoSim::launch(mixed_cfg()).unwrap();
    let mut hook = NoopHook;
    {
        let mut env = GuestEnv::for_device(&mut cosim.vmm, &mut hook, 1);
        let mut drv = SortDriver::for_device(256, 1);
        drv.expect_kernel = Some(KernelKind::Sort);
        let err = drv.probe(&mut env).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        assert!(err.contains("wrong-kernel") || err.contains("refusing"), "{err}");
    }
    // The SG driver shares the probe front half, so it refuses too.
    {
        let mut env = GuestEnv::for_device(&mut cosim.vmm, &mut hook, 2);
        let mut drv = SortDriverSg::new(64, 2, 2);
        drv.drv.expect_kernel = Some(KernelKind::Checksum);
        let err = drv.probe(&mut env).unwrap_err().to_string();
        assert!(err.contains("stats"), "{err}");
    }
    cosim.shutdown_all().unwrap();
}

#[test]
fn probe_adopts_capability_registers_and_subsys_hint() {
    let mut cosim = CoSim::launch(mixed_cfg()).unwrap();
    let mut hook = NoopHook;
    // Device 2 advertises the stats kernel at n=64; an unopinionated
    // driver adopts the probed geometry wholesale (the caller's guess
    // of 1024 is overwritten).
    {
        let mut env = GuestEnv::for_device(&mut cosim.vmm, &mut hook, 2);
        let mut drv = SortDriver::for_device(1024, 2);
        drv.timeout = Duration::from_secs(30);
        drv.probe(&mut env).unwrap();
        assert_eq!(drv.kernel, KernelKind::Stats);
        assert_eq!(drv.n, 64);
        assert_eq!(drv.out_words, 8);
        // The enumeration-level hint matches: the subsystem id names
        // the same kernel the BAR0 capability register reports.
        let subsys = (env.config_read32(cfg_regs::SUBSYS_VENDOR).unwrap() >> 16) as u16;
        assert_eq!(subsys, board::subsys_id_for_kernel(KernelKind::Stats.id()));
        // A record sized for the caller's wrong guess is refused.
        let err = drv.sort_record(&mut env, &[0i32; 1024]).unwrap_err();
        assert!(err.to_string().contains("record length"), "{err}");
        // One correctly-sized record flows end to end.
        let mut rng = XorShift64::new(0xAB5);
        let input = rng.vec_i32(64);
        let out = drv.sort_record(&mut env, &input).unwrap();
        let s = record_stats(&input);
        assert_eq!(out, pack_stats_words(s.min, s.max, s.sum, s.count).to_vec());
    }
    // Device 0 keeps the paper's sort personality (subsystem id
    // byte-identical to the seed board).
    {
        let mut env = GuestEnv::for_device(&mut cosim.vmm, &mut hook, 0);
        let subsys = (env.config_read32(cfg_regs::SUBSYS_VENDOR).unwrap() >> 16) as u16;
        assert_eq!(subsys, board::SUBSYS_ID);
    }
    cosim.shutdown_all().unwrap();
}

#[test]
fn homogeneous_checksum_fleet_runs_through_the_sharded_path() {
    // `--kernel checksum` with no per-device overrides: the whole
    // fleet swaps engines, and the dispatcher routes through the
    // mixed runner (single group).
    let mut cfg = CoSimCfg { devices: 2, ..Default::default() };
    cfg.platform.kernel.kind = KernelKind::Checksum;
    cfg.platform.kernel.n = 256;
    cfg.platform.kernel.latency = KernelKind::Checksum.default_latency(256);
    let records = 5;
    let seed = 0xC5C5;
    let (rep, outs) = scenario::run_sharded_offload_depth(
        cfg,
        records,
        seed,
        ShardPolicy::RoundRobin,
        1,
        None,
    )
    .unwrap();
    assert_eq!(rep.per_device_records.iter().sum::<usize>(), records);
    let mut rng = XorShift64::new(seed);
    for (i, out) in outs.iter().enumerate() {
        let input = rng.vec_i32(256);
        assert_eq!(
            out,
            &pack_checksum_words(record_checksum(&input)).to_vec(),
            "record {i} checksum mismatch"
        );
    }
}
