//! Debug-monitor integration tests against a live co-simulation —
//! the GDB-on-the-VMM workflow of paper §II, end to end.

use std::time::Duration;

use vmhdl::coordinator::cosim::{CoSim, CoSimCfg};
use vmhdl::testutil::XorShift64;
use vmhdl::vm::guest::SortDriver;
use vmhdl::vm::monitor::{Breakpoint, Monitor};

#[test]
fn breakpoint_in_live_offload_then_finish() {
    let cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let hdl = cosim.hdl;
    let mut mon = Monitor::launch(
        cosim.vmm,
        vec![Breakpoint::State("xfer:wait".to_string())],
        |env| {
            let mut drv = SortDriver::new(1024);
            drv.timeout = Duration::from_secs(30);
            drv.probe(env)?;
            let mut rng = XorShift64::new(9);
            let rec = rng.vec_i32(1024);
            let out = drv.sort_record(env, &rec)?;
            let mut e = rec;
            e.sort_unstable();
            Ok(if out == e { "sorted-ok".into() } else { "MISMATCH".into() })
        },
    );
    // We stop exactly while the DMA is in flight.
    let stop = mon.wait_stop(Duration::from_secs(30)).expect("no stop");
    assert!(stop.event.contains("xfer:wait"), "{}", stop.event);
    // Device inspectable while "running": stats show the DMA traffic.
    let info = mon.dev_info().unwrap();
    assert!(info.contains("mmio_writes"), "{info}");
    assert_eq!(mon.finish().unwrap(), "sorted-ok");
    hdl.unwrap().stop().unwrap();
}

#[test]
fn mmio_breakpoint_fires_on_dma_program() {
    use vmhdl::hdl::dma::regs as dregs;
    use vmhdl::vm::guest::driver::DMA_BASE;
    let cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let hdl = cosim.hdl;
    let bp = Breakpoint::Mmio {
        bar: 0,
        offset: DMA_BASE + dregs::MM2S_LENGTH as u64,
    };
    let mut mon = Monitor::launch(cosim.vmm, vec![bp], |env| {
        let mut drv = SortDriver::new(1024);
        drv.timeout = Duration::from_secs(30);
        drv.probe(env)?;
        let mut rng = XorShift64::new(10);
        let rec = rng.vec_i32(1024);
        drv.sort_record(env, &rec)?;
        Ok("done".into())
    });
    let stop = mon.wait_stop(Duration::from_secs(30)).expect("no stop");
    assert!(stop.event.contains("is_write: true"), "{}", stop.event);
    assert_eq!(mon.finish().unwrap(), "done");
    hdl.unwrap().stop().unwrap();
}

#[test]
fn memory_patch_changes_dma_input() {
    // Patch the guest DMA source buffer while stopped at the program
    // step: the hardware must sort the *patched* data — "monitoring or
    // even modifying register and memory contents" (paper §II).
    let cosim = CoSim::launch(CoSimCfg::default()).unwrap();
    let hdl = cosim.hdl;
    let mut mon = Monitor::launch(
        cosim.vmm,
        vec![Breakpoint::State("xfer:program_s2mm".to_string())],
        |env| {
            let mut drv = SortDriver::new(1024);
            drv.timeout = Duration::from_secs(30);
            drv.probe(env)?;
            let src_addr = drv.src.unwrap().addr;
            let rec = vec![5i32; 1024]; // all fives
            let out = drv.sort_record(env, &rec)?;
            Ok(format!("src={src_addr} first={} last={}", out[0], out[1023]))
        },
    );
    let _stop = mon.wait_stop(Duration::from_secs(30)).expect("no stop");
    // The driver staged all-fives; patch word 0 to -7 via the monitor.
    // (Buffer base is deterministic: first allocation in fresh memory.)
    mon.patch_mem(0, (-7i32).to_le_bytes().to_vec());
    let report = mon.finish().unwrap();
    assert!(
        report.contains("first=-7") && report.contains("last=5"),
        "patched value did not flow through the hardware: {report}"
    );
    hdl.unwrap().stop().unwrap();
}
