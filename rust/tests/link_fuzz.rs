//! Link fuzz harness — the offline stand-in for a coverage-guided
//! fuzzer, runnable as a plain `cargo test` (the vendored crate set
//! has no `cargo-fuzz`; `testutil::fuzz` documents the substitution).
//!
//! Two attack surfaces, both the exact production paths:
//!
//! * [`Msg::decode_on`] — mutated valid frames and pure random bytes.
//!   Invariants: never panics, never allocates beyond the frame's own
//!   length (the codec's 16 MiB body cap plus bounds-checked `take`),
//!   and every *accepted* frame re-encodes byte-identically (the codec
//!   accepts only its canonical form).
//! * [`ReliableRx::on_frame`] — adversarial `(seq, msg)` streams.
//!   Invariants: never panics, reorder-buffer occupancy never exceeds
//!   `PENDING_CAP`, each reliable seq is delivered at most once and in
//!   order, and the sequenced-unreliable channel only moves forward.
//!
//! Every case derives from a printed seed, so any failure names the
//! exact reproducer.

use vmhdl::link::channel::PENDING_CAP;
use vmhdl::link::{make_inproc_pair, Msg, ReliableRx};
use vmhdl::testutil::{ByteMutator, XorShift64};

/// Cases per decode-surface run (mutated + random halves). Together
/// with the rx streams below the harness exceeds 100k cases per
/// `cargo test` invocation while staying well under a second.
const DECODE_CASES: usize = 120_000;

/// A random well-formed message with bounded payloads.
fn arbitrary_msg(r: &mut XorShift64) -> Msg {
    let n = r.range(0, 32);
    let data = r.vec_u8(n);
    match r.below(14) {
        0 => Msg::MmioRead {
            tag: r.next_u64(),
            bar: r.next_u64() as u8,
            addr: r.next_u64(),
            len: r.next_u32(),
        },
        1 => Msg::MmioWrite { bar: r.next_u64() as u8, addr: r.next_u64(), data },
        2 => Msg::MmioReadResp { tag: r.next_u64(), data },
        3 => Msg::DmaRead { tag: r.next_u64(), addr: r.next_u64(), len: r.next_u32() },
        4 => Msg::DmaWrite { addr: r.next_u64(), data },
        5 => Msg::Interrupt { vector: r.next_u32() as u16 },
        6 => Msg::DmaReadResp { tag: r.next_u64(), data },
        7 => Msg::Tlp { bytes: data },
        8 => Msg::Hello {
            side_is_vm: r.chance(1, 2),
            session: r.next_u64(),
            last_seq_seen: r.next_u64(),
        },
        9 => Msg::Ack { up_to: r.next_u64() },
        10 => Msg::Bye,
        11 => Msg::Resume { from: r.next_u64() },
        12 => Msg::AckBits { up_to: r.next_u64(), bits: r.next_u32() },
        _ => Msg::StatTick { cycles: r.next_u64(), records_done: r.next_u64() },
    }
}

/// Sequence numbers biased toward a dense window (dups, gaps,
/// reorders) with occasional extremes (0, u64::MAX, anywhere).
fn adversarial_seq(r: &mut XorShift64) -> u64 {
    match r.below(10) {
        0 => r.next_u64(),
        1 => u64::MAX - r.below(4),
        2 => 0,
        _ => r.below(300),
    }
}

#[test]
fn fuzz_decode_never_panics_and_accepted_frames_roundtrip() {
    let mut mutator = ByteMutator::new(0xF00D_F00D);
    let mut rng = XorShift64::new(0xDEC0DE);
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    for case in 0..DECODE_CASES {
        let frame = if case % 2 == 0 {
            let msg = arbitrary_msg(&mut rng);
            let seq = rng.next_u64();
            let dev = rng.next_u64() as u8;
            let mut f = msg.encode_on(seq, dev);
            mutator.mutate(&mut f);
            f
        } else {
            mutator.random_frame(256)
        };
        match Msg::decode_on(&frame) {
            Ok((seq, dev, msg)) => {
                accepted += 1;
                let re = msg.encode_on(seq, dev);
                assert_eq!(
                    re, frame,
                    "case {case}: accepted frame did not re-encode identically"
                );
            }
            Err(_) => rejected += 1,
        }
    }
    // The harness must exercise both outcomes to mean anything.
    assert!(accepted > 1_000, "accept path starved: {accepted} of {DECODE_CASES}");
    assert!(rejected > 1_000, "reject path starved: {rejected} of {DECODE_CASES}");
}

#[test]
fn fuzz_rx_exactly_once_in_order_under_adversarial_sequences() {
    for instance in 0..64u64 {
        let (t, _peer) = make_inproc_pair();
        let mut rx = ReliableRx::new(Box::new(t));
        let mut rng = XorShift64::new(0x5EED_0000 + instance);
        let mut out = Vec::new();
        // Delivery oracles: reliable payloads carry their seq in
        // `addr`, unreliable ticks in `cycles`.
        let mut next_expected = 1u64;
        let mut last_tick = 0u64;
        for case in 0..2_000 {
            let seq = adversarial_seq(&mut rng);
            let unreliable = rng.chance(1, 8);
            let msg = if unreliable {
                Msg::StatTick { cycles: seq, records_done: 0 }
            } else {
                Msg::MmioWrite { bar: 0, addr: seq, data: vec![] }
            };
            out.clear();
            rx.on_frame(seq, msg, &mut out);
            assert!(
                rx.pending_len() <= PENDING_CAP,
                "instance {instance} case {case}: reorder buffer exceeded cap"
            );
            for m in &out {
                match m {
                    Msg::MmioWrite { addr, .. } => {
                        assert_eq!(
                            *addr, next_expected,
                            "instance {instance} case {case}: out-of-order delivery"
                        );
                        next_expected += 1;
                    }
                    Msg::StatTick { cycles, .. } => {
                        assert!(
                            *cycles > last_tick,
                            "instance {instance} case {case}: stale tick delivered"
                        );
                        last_tick = *cycles;
                    }
                    other => panic!("unexpected delivery {other:?}"),
                }
            }
        }
    }
}

#[test]
fn fuzz_rx_arbitrary_messages_bounded_state() {
    // No ordering oracle here — any message kind, any seq. The state
    // machine must stay panic-free and bounded regardless.
    for instance in 0..16u64 {
        let (t, _peer) = make_inproc_pair();
        let mut rx = ReliableRx::new(Box::new(t));
        let mut rng = XorShift64::new(0xA55A_0000 + instance);
        let mut out = Vec::new();
        for _ in 0..2_000 {
            let seq = adversarial_seq(&mut rng);
            let msg = arbitrary_msg(&mut rng);
            out.clear();
            rx.on_frame(seq, msg, &mut out);
            assert!(rx.pending_len() <= PENDING_CAP);
        }
    }
}
