//! Integration tests for `cargo xtask analyze`.
//!
//! Two contracts, both directions:
//!
//! * every fixture under `xtask/fixtures/` trips exactly its intended
//!   rule (the passes can still see the hazards), and nothing else
//!   (the fixtures double as false-positive regressions);
//! * the real tree plus `analysis/allow.toml` is clean — zero
//!   unsuppressed findings AND zero stale allow entries. This is the
//!   same invariant the blocking CI `analyze` job enforces, kept here
//!   so plain `cargo test` catches drift without the CI round-trip.

use std::path::{Path, PathBuf};

use xtask::{analyze, PassSet, Report};

fn fixture_root(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name)
}

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask/ lives one level below the repo root")
        .to_path_buf()
}

/// Run all passes over a fixture with an empty allowlist.
fn scan_fixture(name: &str) -> Report {
    analyze(&fixture_root(name), &[], PassSet::default())
        .unwrap_or_else(|e| panic!("analyze({name}) failed: {e}"))
}

fn assert_only_rule(report: &Report, pass: &str, rule: &str, expect_n: usize) {
    assert_eq!(
        report.findings.len(),
        expect_n,
        "expected exactly {expect_n} finding(s), got:\n{}",
        render(report),
    );
    for f in &report.findings {
        assert_eq!(
            (f.pass, f.rule),
            (pass, rule),
            "unexpected finding:\n{}",
            render(report),
        );
    }
}

fn render(report: &Report) -> String {
    report
        .findings
        .iter()
        .map(|f| f.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn fixture_wall_clock_trips_determinism() {
    let r = scan_fixture("wall_clock");
    assert_only_rule(&r, "determinism", "wall-clock", 1);
    // The Instant::now inside #[cfg(test)] must NOT be flagged; one
    // finding total proves the test-region skip still works.
}

#[test]
fn fixture_hashmap_iter_trips_hash_collections() {
    let r = scan_fixture("hashmap_iter");
    assert!(
        !r.findings.is_empty(),
        "hashmap_iter fixture produced no findings"
    );
    for f in &r.findings {
        assert_eq!((f.pass, f.rule), ("determinism", "hash-collections"));
    }
}

#[test]
fn fixture_undeclared_offset_trips_regmap() {
    let r = scan_fixture("undeclared_offset");
    assert_only_rule(&r, "regmap", "undeclared-offset", 1);
    // The symbolic rf_regs::ID read in the same fn must resolve clean.
}

#[test]
fn fixture_ro_write_trips_regmap() {
    let r = scan_fixture("ro_write");
    assert_only_rule(&r, "regmap", "ro-write", 1);
    // The RW SCRATCH write in the same fn must resolve clean.
}

#[test]
fn fixture_hot_unwrap_trips_panic_audit() {
    let r = scan_fixture("hot_unwrap");
    assert_only_rule(&r, "panic", "unwrap", 1);
    // The unwrap-equivalent inside #[cfg(test)] mod tests is sanctioned.
}

#[test]
fn pass_gating_skips_disabled_passes() {
    // Running only the determinism pass over a regmap-bad fixture must
    // report nothing: --pass selection genuinely disables the others.
    let mut only_det = PassSet::none();
    only_det.enable("determinism").expect("known pass name");
    let r = analyze(&fixture_root("ro_write"), &[], only_det).expect("analyze");
    assert!(
        r.findings.is_empty(),
        "determinism-only run leaked regmap findings:\n{}",
        render(&r),
    );
}

#[test]
fn repo_allowlist_is_scoped_not_blanket() {
    // The repo allowlist must not accidentally suppress fixture-style
    // hazards: its entries are (pass, path, rule, fn)-scoped, so a hot
    // path unwrap in link/msg.rs still fails even with it loaded.
    let allow = xtask::allow::load(&repo_root().join("analysis").join("allow.toml"))
        .expect("allow.toml parses");
    let r = analyze(&fixture_root("hot_unwrap"), &allow, PassSet::default()).expect("analyze");
    assert_eq!(r.findings.len(), 1, "allowlist over-suppressed:\n{}", render(&r));
}

/// The headline invariant: today's tree is clean under today's
/// allowlist, and the allowlist carries no stale entries.
#[test]
fn real_tree_is_clean_under_allowlist() {
    let root = repo_root();
    let allow = xtask::allow::load(&root.join("analysis").join("allow.toml"))
        .expect("allow.toml parses");
    assert!(!allow.is_empty(), "allow.toml should not be empty");
    let r = analyze(&root, &allow, PassSet::default()).expect("analyze");
    assert!(
        r.findings.is_empty(),
        "unsuppressed findings in the real tree:\n{}",
        render(&r),
    );
    assert!(
        r.unused_allows.is_empty(),
        "stale allow entries:\n{}",
        r.unused_allows.join("\n"),
    );
    assert!(r.suppressed > 0, "expected the documented wall seams to be suppressed");
}
