//! Fixture: an unordered container in the deterministic core.
//!
//! Iterating a `HashMap` makes completion-servicing order depend on
//! the hasher's per-process random seed, so two runs of the same
//! scenario replay completions in different orders. The fix is always
//! the same: `BTreeMap` (see `hdl/signal.rs` for the real instance
//! this pass caught).

use std::collections::HashMap;

pub struct CompletionBoard {
    pending: HashMap<u64, u32>,
}

impl CompletionBoard {
    pub fn post(&mut self, tag: u64, len: u32) {
        self.pending.insert(tag, len);
    }

    /// BAD: drain order follows hasher seed, not tag order.
    pub fn drain_in_hash_order(&mut self) -> Vec<(u64, u32)> {
        let out: Vec<(u64, u32)> = self.pending.iter().map(|(k, v)| (*k, *v)).collect();
        self.pending.clear();
        out
    }
}
