//! Fixture: an MMIO read of an offset no register table declares.
//!
//! The classic co-development drift: the RTL moved a register, the
//! driver kept the old magic number. The regmap pass cross-checks
//! every BAR0 literal against the declared windows.

use crate::hdl::regfile::regs as rf_regs;
use crate::vm::guest::GuestEnv;
use crate::Result;

pub const REGFILE_BASE: u64 = 0x0000;

pub fn probe(env: &mut GuestEnv) -> Result<u32> {
    // GOOD: symbolic, declared.
    let id = env.read32(0, REGFILE_BASE + rf_regs::ID as u64)?;
    // BAD: 0x50 is inside the regfile window but declared nowhere.
    let magic = env.read32(0, 0x0050)?;
    Ok(id ^ magic)
}
