//! Fixture register table: two declared registers, nothing at 0x50.

pub mod regs {
    /// RO: device identification word.
    pub const ID: u32 = 0x00;
    /// RW: scratch register for link sanity checks.
    pub const SCRATCH: u32 = 0x08;
}
