//! Fixture: a wall-clock read inside the deterministic core.
//!
//! `Instant::now()` feeding a cycle decision is exactly the hazard the
//! determinism pass exists for — two runs of the same scenario would
//! step different cycle counts depending on host load.

use std::time::Instant;

pub struct Ticker {
    pub cycles: u64,
    pub started: Option<Instant>,
}

impl Ticker {
    /// BAD: steps a variable number of cycles per call depending on
    /// how long the host happened to stall since the last call.
    pub fn tick(&mut self) -> u64 {
        let now = Instant::now();
        if let Some(prev) = self.started.replace(now) {
            let elapsed = now.duration_since(prev).as_micros() as u64;
            self.cycles += elapsed.max(1);
        }
        self.cycles
    }

    /// GOOD (and must NOT be flagged): test code may use the wall
    /// clock freely.
    #[cfg(test)]
    pub fn wall_reference() -> Instant {
        Instant::now()
    }
}
