//! Fixture: a write to a register the table declares read-only.
//!
//! The RTL silently drops the write, so the bug surfaces far away as
//! "the device ignored my configuration". The regmap pass turns it
//! into a build failure at the offending line instead.

use crate::hdl::regfile::regs as rf_regs;
use crate::vm::guest::GuestEnv;
use crate::Result;

pub const REGFILE_BASE: u64 = 0x0000;

pub fn scribble(env: &mut GuestEnv) -> Result<()> {
    // GOOD: SCRATCH is RW.
    env.write32(0, REGFILE_BASE + rf_regs::SCRATCH as u64, 0xA5A5_5A5A)?;
    // BAD: ID is RO.
    env.write32(0, REGFILE_BASE + rf_regs::ID as u64, 0xDEAD_BEEF)?;
    Ok(())
}
