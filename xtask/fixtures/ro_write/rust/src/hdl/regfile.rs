//! Fixture register table: a read-only ID and a writable scratch.

pub mod regs {
    /// RO: device identification word — writes are dropped by the RTL.
    pub const ID: u32 = 0x00;
    /// RW: scratch register for link sanity checks.
    pub const SCRATCH: u32 = 0x08;
}
