//! Fixture: a panic path on the link hot path.
//!
//! Every byte here arrives from the peer process; a malformed frame
//! must surface as `Error::link`, never as a panic that takes the
//! whole co-simulation down. The panic pass forbids `.unwrap()` /
//! `.expect()` / `panic!` / slice indexing in this file outside tests.

pub fn parse_len(frame: &[u8]) -> u32 {
    // BAD: a short frame from the peer panics the VM-side process.
    let hdr: [u8; 4] = frame.get(..4).map(|b| b.try_into().ok()).flatten().unwrap();
    u32::from_le_bytes(hdr)
}

#[cfg(test)]
mod tests {
    use super::parse_len;

    #[test]
    fn parses_little_endian() {
        // unwrap in tests is sanctioned — this must NOT be flagged
        // beyond the one hot-path finding above.
        assert_eq!(parse_len(&[1, 0, 0, 0]), 1);
    }
}
