//! Panic-path audit.
//!
//! The link layer is fed by a peer process over a socket; the driver
//! reap path runs against device-written guest memory. Both consume
//! *external* input, so a malformed byte stream must surface as
//! `Error::link`/`Error::vm`, never as a panic that tears down the
//! co-simulation (the one sanctioned panic seam is the lane
//! `catch_unwind` boundary in `coordinator/cosim.rs`, which converts
//! HDL model panics into `Error::hdl`). Outside `#[cfg(test)]` this
//! pass forbids, in the scoped files:
//!
//! * `unwrap` / `expect` — `.unwrap()` / `.expect(…)` calls
//!   (`unwrap_or_else(|e| e.into_inner())`-style non-panicking forms
//!   are fine and not matched);
//! * `panic-macro` — `panic!`, `unreachable!`, `todo!`,
//!   `unimplemented!`;
//! * `slice-index` — `x[…]` indexing in the `link/` hot path, where
//!   every length field is attacker-ish input; use `get`-based
//!   slicing. (Pattern positions like `let [a, b] = …` and types like
//!   `&'a [u8]` are recognized and skipped.)

use crate::scan::{is_ident, SourceFile};
use crate::Finding;

/// Files whose non-test code must be panic-free.
const SCOPE: [&str; 10] = [
    "link/msg.rs",
    "link/channel.rs",
    "link/transport.rs",
    "link/udp.rs",
    "link/impair.rs",
    "link/recorder.rs",
    "coordinator/replay.rs",
    "vm/guest/driver.rs",
    "pcie/tlp.rs",
    "pcie/fault.rs",
];

/// Slice-indexing is additionally forbidden here (the wire hot path).
const INDEX_SCOPE_PREFIX: &str = "link/";

pub fn run(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files.iter().filter(|f| SCOPE.contains(&f.rel.as_str())) {
        for (a, b) in f.words() {
            if f.is_test(a) {
                continue;
            }
            match f.word(a, b) {
                w @ ("unwrap" | "expect") => {
                    let dotted = a > 0
                        && f.prev_nonws(a - 1).is_some_and(|p| f.code[p] == b'.');
                    let called = f.code.get(f.next_nonws(b)) == Some(&b'(');
                    if dotted && called {
                        out.push(finding(
                            f,
                            a,
                            if w == "unwrap" { "unwrap" } else { "expect" },
                            format!(".{w}() on a hot path fed by external input"),
                            "propagate an Error::link/Error::vm instead \
                             (map_err / ok_or_else / let-else)",
                        ));
                    }
                }
                w @ ("panic" | "unreachable" | "todo" | "unimplemented") => {
                    if f.code.get(f.next_nonws(b)) == Some(&b'!') {
                        out.push(finding(
                            f,
                            a,
                            "panic-macro",
                            format!("`{w}!` in a hot path fed by external input"),
                            "return an error; the only sanctioned panic seam is the \
                             lane catch_unwind boundary in coordinator/cosim.rs",
                        ));
                    }
                }
                _ => {}
            }
        }
        if f.rel.starts_with(INDEX_SCOPE_PREFIX) {
            scan_indexing(f, &mut out);
        }
    }
    out
}

/// Keywords that legitimately precede `[` without it being an index
/// expression (patterns, array types/literals).
const PRE_BRACKET_KEYWORDS: [&str; 10] = [
    "let", "mut", "ref", "in", "return", "else", "match", "move", "box", "dyn",
];

fn scan_indexing(f: &SourceFile, out: &mut Vec<Finding>) {
    for (i, &byte) in f.code.iter().enumerate() {
        if byte != b'[' || f.is_test(i) || i == 0 {
            continue;
        }
        let Some(p) = f.prev_nonws(i - 1) else {
            continue;
        };
        let prev = f.code[p];
        if !(is_ident(prev) || prev == b')' || prev == b']') {
            continue;
        }
        if is_ident(prev) {
            // Walk back over the identifier; skip keywords and
            // lifetimes (`&'a [u8]`).
            let mut s = p;
            while s > 0 && is_ident(f.code[s - 1]) {
                s -= 1;
            }
            let word = f.word(s, p + 1);
            if PRE_BRACKET_KEYWORDS.contains(&word) {
                continue;
            }
            if s > 0 && f.code[s - 1] == b'\'' {
                continue;
            }
        }
        out.push(finding(
            f,
            i,
            "slice-index",
            "slice/array indexing in the link hot path (panics on \
             out-of-range input)"
                .to_string(),
            "use .get(..)/.get_mut(..) and surface Error::link on miss",
        ));
    }
}

fn finding(
    f: &SourceFile,
    off: usize,
    rule: &'static str,
    message: String,
    remedy: &'static str,
) -> Finding {
    Finding {
        pass: "panic",
        rule,
        path: f.rel.clone(),
        line: f.line_of(off),
        func: f.enclosing_fn(off).map(str::to_string),
        message,
        remedy,
    }
}
