//! Register-map consistency pass.
//!
//! The chronic HW/SW co-development failure mode (PAPERS.md:
//! Kruszewski; Zabołotny's QEMU-DAQ) is the driver and the RTL
//! disagreeing about the register map. This pass makes the agreement
//! checkable on every commit:
//!
//! 1. Extract the register tables from `hdl/regfile.rs` and
//!    `hdl/dma.rs`: every `pub const NAME: u32 = OFFSET;` inside
//!    `pub mod regs`, with its access attribute taken from the first
//!    doc-comment token (`RO:` / `RW:` / `W1C:` / `WO:`). A constant
//!    without a marker is itself a finding (`missing-attr`).
//! 2. Walk every `readN(bar, offset…)` / `writeN(bar, offset…, v)`
//!    MMIO call in `vm/guest/driver.rs` and `vm/guest/app.rs` (BAR0
//!    only — that's where the regfile @0x0000 and DMA @0x1000 windows
//!    live) and check each site against the tables:
//!    * `undeclared-offset` — literal offset not in any table;
//!    * `ro-write` / `wo-read` — access forbidden by the attribute;
//!    * `width-mismatch` — non-32-bit access to a 32-bit register;
//!    * `base-mismatch` — `dma_regs::` constant used without
//!      `DMA_BASE` (or `rf_regs::` beyond the regfile window);
//!    * `unresolved-offset` — the offset expression is not statically
//!      resolvable (e.g. a register held in a local); such sites need
//!      an allow entry explaining where the offset comes from.

use std::collections::BTreeMap;

use crate::scan::{match_paren, SourceFile, Words};
use crate::Finding;

const REGFILE: &str = "hdl/regfile.rs";
const DMA: &str = "hdl/dma.rs";
const DRIVERS: [&str; 2] = ["vm/guest/driver.rs", "vm/guest/app.rs"];

const REGFILE_BASE: u64 = 0x0000;
const DMA_BASE: u64 = 0x1000;
/// Each BAR0 window is 4 KiB (see `hdl/platform`).
const WINDOW: u64 = 0x1000;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Attr {
    Ro,
    Rw,
    W1c,
    Wo,
}

impl Attr {
    fn parse(s: &str) -> Option<Attr> {
        match s {
            "RO" => Some(Attr::Ro),
            "RW" => Some(Attr::Rw),
            "W1C" => Some(Attr::W1c),
            "WO" => Some(Attr::Wo),
            _ => None,
        }
    }

    fn writable(self) -> bool {
        !matches!(self, Attr::Ro)
    }

    fn readable(self) -> bool {
        !matches!(self, Attr::Wo)
    }
}

#[derive(Debug, Clone)]
struct RegDef {
    name: String,
    offset: u64,
    attr: Option<Attr>,
}

struct RegTable {
    /// Module path prefix driver code uses (`rf_regs` / `dma_regs`).
    alias: &'static str,
    base: u64,
    by_name: BTreeMap<String, RegDef>,
    by_offset: BTreeMap<u64, String>,
}

pub fn run(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(rf_file) = files.iter().find(|f| f.rel == REGFILE) else {
        // No register file under this root (analyzer fixture trees):
        // nothing to cross-check.
        return out;
    };
    let rf = parse_table(rf_file, "rf_regs", REGFILE_BASE, &mut out);
    let dma = files
        .iter()
        .find(|f| f.rel == DMA)
        .map(|f| parse_table(f, "dma_regs", DMA_BASE, &mut out));
    let tables: Vec<&RegTable> = std::iter::once(&rf).chain(dma.as_ref()).collect();

    for f in files.iter().filter(|f| DRIVERS.contains(&f.rel.as_str())) {
        check_sites(f, &tables, &mut out);
    }
    out
}

/// Extract the `pub mod regs` table of `file`, emitting `missing-attr`
/// findings for constants without an access marker.
fn parse_table(
    file: &SourceFile,
    alias: &'static str,
    base: u64,
    out: &mut Vec<Finding>,
) -> RegTable {
    let mut table = RegTable {
        alias,
        base,
        by_name: BTreeMap::new(),
        by_offset: BTreeMap::new(),
    };
    let Some(mod_start) = find_subslice(&file.code, b"pub mod regs") else {
        return table;
    };
    let Some(open_rel) = file.code[mod_start..].iter().position(|&b| b == b'{') else {
        return table;
    };
    let open = mod_start + open_rel;
    let close = crate::scan::match_brace(&file.code, open);
    let first_line = file.line_of(open);
    let last_line = file.line_of(close);

    let mut pending: Option<Attr> = None;
    for (idx, raw_line) in file.raw.lines().enumerate() {
        let lineno = idx + 1;
        if lineno < first_line || lineno > last_line {
            continue;
        }
        let t = raw_line.trim();
        if let Some(doc) = t.strip_prefix("///") {
            let doc = doc.trim_start();
            if let Some((head, _)) = doc.split_once(':') {
                if let Some(a) = Attr::parse(head.trim()) {
                    pending = Some(a);
                }
            }
            continue;
        }
        if let Some(rest) = t.strip_prefix("pub const ") {
            let Some((name, rest)) = rest.split_once(':') else {
                continue;
            };
            let Some((_ty, value)) = rest.split_once('=') else {
                continue;
            };
            let value = value.trim().trim_end_matches(';').trim();
            let Some(offset) = parse_int(value) else {
                continue;
            };
            let name = name.trim().to_string();
            let attr = pending.take();
            if attr.is_none() {
                out.push(Finding {
                    pass: "regmap",
                    rule: "missing-attr",
                    path: file.rel.clone(),
                    line: lineno,
                    func: None,
                    message: format!(
                        "register constant `{name}` has no access attribute marker"
                    ),
                    remedy: "prefix its doc comment with `RO:`, `RW:`, `W1C:` or `WO:`",
                });
            }
            table.by_offset.insert(offset, name.clone());
            table.by_name.insert(name.clone(), RegDef { name, offset, attr });
        }
    }
    table
}

/// Scan one driver file for MMIO call sites and check them.
fn check_sites(file: &SourceFile, tables: &[&RegTable], out: &mut Vec<Finding>) {
    let accessors: [(&str, bool, u32); 8] = [
        ("read8", false, 1),
        ("read16", false, 2),
        ("read32", false, 4),
        ("read64", false, 8),
        ("write8", true, 1),
        ("write16", true, 2),
        ("write32", true, 4),
        ("write64", true, 8),
    ];
    for (a, b) in Words::new(&file.code) {
        if file.is_test(a) {
            continue;
        }
        let word = file.word(a, b);
        let Some(&(_, is_write, width)) = accessors.iter().find(|(n, _, _)| *n == word) else {
            continue;
        };
        let open = file.next_nonws(b);
        if file.code.get(open) != Some(&b'(') {
            continue;
        }
        let close = match_paren(&file.code, open);
        let args = split_args(&file.code[open + 1..close]);
        if args.len() < 2 {
            continue;
        }
        // Only BAR0 carries the declared register windows.
        if parse_int(&args[0]) != Some(0) {
            continue;
        }
        let site = Site {
            file,
            off: a,
            is_write,
            width,
        };
        check_offset_expr(&site, &args[1], tables, out);
    }
}

struct Site<'a> {
    file: &'a SourceFile,
    off: usize,
    is_write: bool,
    width: u32,
}

fn check_offset_expr(site: &Site<'_>, expr: &str, tables: &[&RegTable], out: &mut Vec<Finding>) {
    let mut e = expr.to_string();
    // Strip integer casts (whitespace is already gone).
    for cast in ["asu64", "asu32", "asu16", "asusize"] {
        while let Some(stripped) = e.strip_suffix(cast) {
            e = stripped.to_string();
        }
    }
    // Peel a named base prefix.
    let mut named_base: Option<&str> = None;
    for base in ["REGFILE_BASE+", "DMA_BASE+"] {
        if let Some(rest) = e.strip_prefix(base) {
            named_base = Some(base.trim_end_matches('+'));
            e = rest.to_string();
            break;
        }
    }

    // Symbolic register reference?
    for t in tables {
        let prefix = format!("{}::", t.alias);
        if let Some(name) = e.strip_prefix(&prefix) {
            let expect_base = if t.base == 0 { None } else { Some("DMA_BASE") };
            let base_ok = match (named_base, expect_base) {
                (Some("REGFILE_BASE") | None, None) => true,
                (Some("DMA_BASE"), Some("DMA_BASE")) => true,
                _ => false,
            };
            if !base_ok {
                emit(
                    site,
                    "base-mismatch",
                    format!("`{}::{name}` addressed through the wrong window base", t.alias),
                    "pair rf_regs with REGFILE_BASE and dma_regs with DMA_BASE",
                    out,
                );
                return;
            }
            match t.by_name.get(name) {
                Some(def) => check_attr(site, t, def, out),
                None => emit(
                    site,
                    "undeclared-offset",
                    format!("`{}::{name}` is not declared in the register table", t.alias),
                    "declare the register (with an access attribute) in the regs module",
                    out,
                ),
            }
            return;
        }
    }

    // Literal offset?
    if let Some(v) = parse_int(&e) {
        let base = named_base.map_or(0, |b| if b == "DMA_BASE" { DMA_BASE } else { REGFILE_BASE });
        let abs = v + base;
        for t in tables {
            if abs >= t.base && abs < t.base + WINDOW {
                match t.by_offset.get(&(abs - t.base)) {
                    Some(name) => {
                        let def = &t.by_name[name];
                        check_attr(site, t, def, out);
                    }
                    None => emit(
                        site,
                        "undeclared-offset",
                        format!("literal offset {abs:#x} matches no declared register"),
                        "use the declared `rf_regs::`/`dma_regs::` constant, or declare it",
                        out,
                    ),
                }
                return;
            }
        }
        emit(
            site,
            "undeclared-offset",
            format!("literal offset {abs:#x} is outside every declared register window"),
            "BAR0 registers live in 0x0000..0x2000; declare the register first",
            out,
        );
        return;
    }

    emit(
        site,
        "unresolved-offset",
        format!("offset expression `{expr}` is not statically resolvable"),
        "reference `rf_regs::`/`dma_regs::` constants directly at the call site, \
         or allowlist the site with a reason naming where the offset comes from",
        out,
    );
}

fn check_attr(site: &Site<'_>, t: &RegTable, def: &RegDef, out: &mut Vec<Finding>) {
    let Some(attr) = def.attr else {
        // Declaration-side finding already emitted by parse_table.
        return;
    };
    if site.is_write && !attr.writable() {
        emit(
            site,
            "ro-write",
            format!("write to read-only register `{}::{}`", t.alias, def.name),
            "drop the write, or fix the register's attribute in the regs module \
             if the hardware actually accepts it",
            out,
        );
    }
    if !site.is_write && !attr.readable() {
        emit(
            site,
            "wo-read",
            format!("read of write-only register `{}::{}`", t.alias, def.name),
            "drop the read, or fix the register's attribute",
            out,
        );
    }
    if site.width != 4 {
        emit(
            site,
            "width-mismatch",
            format!(
                "{}-byte access to 32-bit register `{}::{}` (offset {:#x})",
                site.width, t.alias, def.name, def.offset
            ),
            "all platform registers are 32-bit; use read32/write32",
            out,
        );
    }
}

fn emit(
    site: &Site<'_>,
    rule: &'static str,
    message: String,
    remedy: &'static str,
    out: &mut Vec<Finding>,
) {
    out.push(Finding {
        pass: "regmap",
        rule,
        path: site.file.rel.clone(),
        line: site.file.line_of(site.off),
        func: site.file.enclosing_fn(site.off).map(str::to_string),
        message,
        remedy,
    });
}

/// Split a (comment-stripped) argument byte range on top-level commas,
/// returning whitespace-free strings; trailing empties are dropped.
fn split_args(bytes: &[u8]) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut depth = 0i64;
    for &b in bytes {
        match b {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            _ => {}
        }
        if b == b',' && depth == 0 {
            parts.push(std::mem::take(&mut cur));
            continue;
        }
        if !(b as char).is_whitespace() {
            cur.push(b as char);
        }
    }
    parts.push(cur);
    while parts.last().is_some_and(|p| p.is_empty()) {
        parts.pop();
    }
    parts
}

/// Parse a decimal or `0x` hex literal (with `_` separators).
fn parse_int(s: &str) -> Option<u64> {
    let s = s.replace('_', "");
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_args_handles_nesting() {
        let v = split_args(b"0, DMA_BASE + regs::X as u64, f(a, b), ");
        assert_eq!(v, vec!["0", "DMA_BASE+regs::Xasu64", "f(a,b)"]);
    }

    #[test]
    fn parse_int_hex_and_dec() {
        assert_eq!(parse_int("0x1C"), Some(0x1C));
        assert_eq!(parse_int("0x5A5A_A5A5"), Some(0x5A5A_A5A5));
        assert_eq!(parse_int("12"), Some(12));
        assert_eq!(parse_int("rf_regs::ID"), None);
    }
}
