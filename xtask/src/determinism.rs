//! Determinism lint.
//!
//! The framework's core invariant (PR 1, EXPERIMENTS.md §"cycle
//! determinism"): device cycles are a pure function of the link
//! message sequence. Anything that lets *wall* time or ambient
//! randomness influence the deterministic core breaks same-seed
//! reproducibility, so inside the scoped paths this pass flags:
//!
//! * `wall-clock` — `Instant::now`, any `SystemTime` use;
//! * `wall-sleep` — `sleep(…)` calls (wall pacing; the
//!   `set_send_latency` sleeper and socket nap-polls are the known
//!   sanctioned seams, each allowlisted with a reason);
//! * `ambient-randomness` — `thread_rng`, `from_entropy` (all
//!   scenario randomness must flow from the seeded `XorShift64`);
//! * `hash-collections` — `HashMap`/`HashSet`: iteration order is
//!   hash-seed dependent, so ordered containers (`BTreeMap`/
//!   `BTreeSet`) are required in the deterministic core.

use crate::scan::SourceFile;
use crate::Finding;

/// Paths (relative to `rust/src`) forming the deterministic core.
const SCOPE_DIRS: [&str; 4] = ["hdl/", "pcie/", "link/", "vm/guest/"];
const SCOPE_FILES: [&str; 3] =
    ["coordinator/scenario.rs", "coordinator/cosim.rs", "coordinator/lanepool.rs"];

pub fn in_scope(rel: &str) -> bool {
    SCOPE_DIRS.iter().any(|d| rel.starts_with(d)) || SCOPE_FILES.contains(&rel)
}

pub fn run(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files.iter().filter(|f| in_scope(&f.rel)) {
        for (a, b) in f.words() {
            if f.is_test(a) {
                continue;
            }
            match f.word(a, b) {
                "Instant" => {
                    if is_instant_now(f, b) {
                        out.push(finding(
                            f,
                            a,
                            "wall-clock",
                            "wall-clock read (`Instant::now`) in the deterministic core",
                            "derive deadlines from cycle/poll counts; if this is a \
                             sanctioned wall seam, add an allow entry with a reason",
                        ));
                    }
                }
                "SystemTime" => out.push(finding(
                    f,
                    a,
                    "wall-clock",
                    "wall-clock type (`SystemTime`) in the deterministic core",
                    "wall time must not feed simulated state; allowlist only \
                     reporting-path uses",
                )),
                "thread_rng" | "from_entropy" => out.push(finding(
                    f,
                    a,
                    "ambient-randomness",
                    "ambient randomness in the deterministic core",
                    "thread all randomness from the scenario seed (`XorShift64`)",
                )),
                "sleep" => {
                    let j = f.next_nonws(b);
                    if f.code.get(j) == Some(&b'(') {
                        out.push(finding(
                            f,
                            a,
                            "wall-sleep",
                            "wall sleep in the deterministic core",
                            "block on the link doorbell/horizon instead; allowlist \
                             known nap-poll seams with a reason",
                        ));
                    }
                }
                "HashMap" | "HashSet" => out.push(finding(
                    f,
                    a,
                    "hash-collections",
                    "hash-seeded container in the deterministic core \
                     (iteration order is unstable across runs)",
                    "use BTreeMap/BTreeSet (or justify why iteration order \
                     can never be observed)",
                )),
                _ => {}
            }
        }
    }
    out
}

/// True if the token after `after_instant` spells `::now`.
fn is_instant_now(f: &SourceFile, after_instant: usize) -> bool {
    let j = f.next_nonws(after_instant);
    if f.code.get(j) != Some(&b':') || f.code.get(j + 1) != Some(&b':') {
        return false;
    }
    let k = f.next_nonws(j + 2);
    f.code[k..].starts_with(b"now")
        && f.code.get(k + 3).map_or(true, |&c| !crate::scan::is_ident(c))
}

fn finding(
    f: &SourceFile,
    off: usize,
    rule: &'static str,
    msg: &str,
    remedy: &'static str,
) -> Finding {
    Finding {
        pass: "determinism",
        rule,
        path: f.rel.clone(),
        line: f.line_of(off),
        func: f.enclosing_fn(off).map(str::to_string),
        message: msg.to_string(),
        remedy,
    }
}
