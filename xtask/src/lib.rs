//! Repo-specific static analysis for the co-simulation core, exposed
//! as `cargo xtask analyze` (see `src/main.rs` for the CLI).
//!
//! Three passes (each its own module, each documenting the invariant
//! it enforces):
//!
//! * [`determinism`] — no wall clock / ambient randomness / unordered
//!   containers in the deterministic core;
//! * [`regmap`] — driver MMIO sites agree with the register tables in
//!   `hdl/regfile.rs` + `hdl/dma.rs` (offsets, RO/RW/W1C, widths);
//! * [`panic_audit`] — no panic paths in the link layer / driver reap
//!   code that external input can reach.
//!
//! Findings are matched against `analysis/allow.toml`; the remainder
//! fail the build. Unused allow entries are reported so the allowlist
//! cannot rot. Everything is zero-dependency std so it builds in the
//! offline container; see each pass for the lexical approximations
//! this implies.

pub mod allow;
pub mod determinism;
pub mod panic_audit;
pub mod regmap;
pub mod scan;

use std::fmt;
use std::io;
use std::path::{Path, PathBuf};

use allow::AllowEntry;
use scan::SourceFile;

/// One diagnostic from a pass.
#[derive(Debug, Clone)]
pub struct Finding {
    pub pass: &'static str,
    pub rule: &'static str,
    /// Path relative to the `rust/src` scan root, `/`-separated.
    pub path: String,
    pub line: usize,
    /// Innermost enclosing named fn, if any.
    pub func: Option<String>,
    pub message: String,
    pub remedy: &'static str,
}

impl fmt::Display for Finding {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            w,
            "rust/src/{}:{}: [{}/{}] {}{}",
            self.path,
            self.line,
            self.pass,
            self.rule,
            self.message,
            self.func
                .as_deref()
                .map(|f| format!(" (in fn {f})"))
                .unwrap_or_default(),
        )?;
        write!(w, "    remedy: {}", self.remedy)
    }
}

/// Which passes to run.
#[derive(Debug, Clone, Copy)]
pub struct PassSet {
    pub determinism: bool,
    pub regmap: bool,
    pub panic: bool,
}

impl Default for PassSet {
    fn default() -> Self {
        PassSet { determinism: true, regmap: true, panic: true }
    }
}

impl PassSet {
    pub fn none() -> Self {
        PassSet { determinism: false, regmap: false, panic: false }
    }

    pub fn enable(&mut self, name: &str) -> Result<(), String> {
        match name {
            "determinism" => self.determinism = true,
            "regmap" => self.regmap = true,
            "panic" => self.panic = true,
            other => return Err(format!("unknown pass `{other}`")),
        }
        Ok(())
    }
}

/// Result of an analysis run.
#[derive(Debug, Default)]
pub struct Report {
    /// Findings NOT covered by the allowlist — these fail the build.
    pub findings: Vec<Finding>,
    /// Count of findings suppressed by allow entries.
    pub suppressed: usize,
    /// Allow entries that matched nothing (stale — should be pruned).
    pub unused_allows: Vec<String>,
}

/// Run the configured passes over `<root>/rust/src`.
pub fn analyze(root: &Path, allow: &[AllowEntry], passes: PassSet) -> io::Result<Report> {
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("scan root {} is not a directory", src.display()),
        ));
    }
    let files = load_tree(&src)?;

    let mut all = Vec::new();
    if passes.determinism {
        all.extend(determinism::run(&files));
    }
    if passes.regmap {
        all.extend(regmap::run(&files));
    }
    if passes.panic {
        all.extend(panic_audit::run(&files));
    }
    all.sort_by(|x, y| {
        (x.path.as_str(), x.line, x.pass, x.rule).cmp(&(y.path.as_str(), y.line, y.pass, y.rule))
    });

    let mut used = vec![false; allow.len()];
    let mut report = Report::default();
    for f in all {
        let mut hit = false;
        for (i, e) in allow.iter().enumerate() {
            if e.matches(&f) {
                used[i] = true;
                hit = true;
            }
        }
        if hit {
            report.suppressed += 1;
        } else {
            report.findings.push(f);
        }
    }
    report.unused_allows = allow
        .iter()
        .zip(&used)
        .filter(|(_, u)| !**u)
        .map(|(e, _)| e.describe())
        .collect();
    Ok(report)
}

/// Load every `.rs` file under `src` (sorted, recursive).
fn load_tree(src: &Path) -> io::Result<Vec<SourceFile>> {
    let mut paths = Vec::new();
    collect(src, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let raw = std::fs::read_to_string(&p)?;
        let rel = p
            .strip_prefix(src)
            .map_err(|e| io::Error::other(e.to_string()))?
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        files.push(SourceFile::new(rel, raw));
    }
    Ok(files)
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
