//! Lexical preprocessing shared by every analysis pass.
//!
//! The analyzer is deliberately a *token scanner*, not a full parser:
//! the container toolchain is offline, so `xtask` must build with zero
//! dependencies (no `syn`). The passes only need four things, all
//! computable lexically:
//!
//! 1. `code`: the source with comments, string/char literals blanked
//!    out (byte-for-byte, newlines preserved) so keyword/identifier
//!    matches never fire inside text.
//! 2. `#[cfg(test)]` (and `#[cfg(loom)]`) item spans, so test-only
//!    code is exempt.
//! 3. Enclosing-`fn` spans, so allowlist entries can be scoped to a
//!    function instead of a whole file.
//! 4. Line numbers for diagnostics.

/// One preprocessed source file.
pub struct SourceFile {
    /// Path relative to the scan root (`rust/src`), `/`-separated.
    pub rel: String,
    /// Original text (used for doc-comment attribute parsing).
    pub raw: String,
    /// Comment/string-blanked copy, same byte length as `raw`.
    pub code: Vec<u8>,
    line_starts: Vec<usize>,
    test_regions: Vec<(usize, usize)>,
    fns: Vec<FnSpan>,
}

struct FnSpan {
    name: String,
    open: usize,
    close: usize,
}

pub fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn blank(out: &mut [u8], a: usize, b: usize) {
    let end = b.min(out.len());
    for x in out.iter_mut().take(end).skip(a) {
        if *x != b'\n' && *x != b'\r' {
            *x = b' ';
        }
    }
}

/// Blank comments, string literals and char literals, preserving byte
/// offsets and newlines. Lifetimes (`'a`) are left in place.
pub fn strip_code(src: &[u8]) -> Vec<u8> {
    let mut out = src.to_vec();
    let n = src.len();
    let mut i = 0usize;
    while i < n {
        let c = src[i];
        if c == b'/' && i + 1 < n && src[i + 1] == b'/' {
            let mut j = i;
            while j < n && src[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && i + 1 < n && src[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if src[j] == b'/' && j + 1 < n && src[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if src[j] == b'*' && j + 1 < n && src[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'r' && (i == 0 || !is_ident(src[i - 1])) && raw_string_len(src, i) > 0 {
            let j = i + raw_string_len(src, i);
            blank(&mut out, i, j);
            i = j;
        } else if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if src[j] == b'\\' {
                    j = (j + 2).min(n);
                } else if src[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'\'' {
            if i + 1 < n && src[i + 1] == b'\\' {
                // Escaped char literal: '\n', '\x7f', '\u{..}'.
                let mut j = i + 2;
                while j < n && src[j] != b'\'' {
                    j += 1;
                }
                let end = (j + 1).min(n);
                blank(&mut out, i, end);
                i = end;
            } else if i + 2 < n && src[i + 2] == b'\'' && src[i + 1] != b'\'' {
                // Plain one-byte char literal: 'x'.
                blank(&mut out, i, i + 3);
                i += 3;
            } else {
                // Lifetime (or a multi-byte char literal, which no
                // pass keyword can match anyway).
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

/// If `src[i..]` starts a raw string literal (`r"…"`, `r#"…"#`, …),
/// return its total byte length, else 0.
fn raw_string_len(src: &[u8], i: usize) -> usize {
    let n = src.len();
    let mut j = i + 1;
    let mut hashes = 0usize;
    while j < n && src[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || src[j] != b'"' {
        return 0;
    }
    j += 1;
    while j < n {
        if src[j] == b'"' {
            let mut k = j + 1;
            let mut h = 0usize;
            while k < n && h < hashes && src[k] == b'#' {
                h += 1;
                k += 1;
            }
            if h == hashes {
                return k - i;
            }
        }
        j += 1;
    }
    n - i
}

/// Index of the matching `}` for the `{` at `open` (or EOF).
pub fn match_brace(code: &[u8], open: usize) -> usize {
    let mut depth = 0i64;
    let mut j = open;
    while j < code.len() {
        match code[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    code.len().saturating_sub(1)
}

/// Index of the matching `)` for the `(` at `open` (or EOF).
pub fn match_paren(code: &[u8], open: usize) -> usize {
    let mut depth = 0i64;
    let mut j = open;
    while j < code.len() {
        match code[j] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    code.len().saturating_sub(1)
}

/// Spans of items gated behind `#[cfg(test)]`/`#[cfg(all(test, …))]`
/// (and `loom` likewise — model-only code is not production code).
fn test_regions(code: &[u8]) -> Vec<(usize, usize)> {
    let n = code.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        if code[i] != b'#' {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        while j < n && (code[j] as char).is_whitespace() {
            j += 1;
        }
        if j >= n || code[j] != b'[' {
            i += 1;
            continue;
        }
        let Some(close) = code[j..].iter().position(|&b| b == b']').map(|p| j + p) else {
            break;
        };
        let attr: String = code[j + 1..close]
            .iter()
            .map(|&b| b as char)
            .filter(|c| !c.is_whitespace())
            .collect();
        let gated = attr.starts_with("cfg(test")
            || attr.starts_with("cfg(loom")
            || attr.starts_with("cfg(all(test")
            || attr.starts_with("cfg(all(loom")
            || attr.starts_with("cfg(any(test");
        if !gated {
            i = close + 1;
            continue;
        }
        // Skip any further attributes, then find the item's body.
        let mut k = close + 1;
        loop {
            while k < n && (code[k] as char).is_whitespace() {
                k += 1;
            }
            if k < n && code[k] == b'#' {
                match code[k..].iter().position(|&b| b == b']') {
                    Some(p) => k += p + 1,
                    None => break,
                }
            } else {
                break;
            }
        }
        let mut brace = None;
        let mut m = k;
        while m < n {
            if code[m] == b';' {
                break;
            }
            if code[m] == b'{' {
                brace = Some(m);
                break;
            }
            m += 1;
        }
        match brace {
            Some(b) => {
                let end = match_brace(code, b);
                out.push((i, end));
                i = b + 1;
            }
            None => i = k.max(i + 1),
        }
    }
    out
}

/// Named-function body spans (lexical; closures are attributed to the
/// nearest enclosing named fn).
fn fn_spans(code: &[u8]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for (a, b) in Words::new(code) {
        if &code[a..b] != b"fn" {
            continue;
        }
        // Next word is the fn name.
        let mut j = b;
        while j < code.len() && (code[j] as char).is_whitespace() {
            j += 1;
        }
        let start = j;
        while j < code.len() && is_ident(code[j]) {
            j += 1;
        }
        if j == start {
            continue;
        }
        let name: String = code[start..j].iter().map(|&c| c as char).collect();
        let mut brace = None;
        let mut m = j;
        while m < code.len() {
            if code[m] == b';' {
                break;
            }
            if code[m] == b'{' {
                brace = Some(m);
                break;
            }
            m += 1;
        }
        if let Some(open) = brace {
            out.push(FnSpan { name, open, close: match_brace(code, open) });
        }
    }
    out
}

fn line_starts(src: &[u8]) -> Vec<usize> {
    let mut v = vec![0usize];
    for (i, b) in src.iter().enumerate() {
        if *b == b'\n' {
            v.push(i + 1);
        }
    }
    v
}

impl SourceFile {
    pub fn new(rel: String, raw: String) -> SourceFile {
        let code = strip_code(raw.as_bytes());
        let line_starts = line_starts(raw.as_bytes());
        let test_regions = test_regions(&code);
        let fns = fn_spans(&code);
        SourceFile { rel, raw, code, line_starts, test_regions, fns }
    }

    /// 1-based line number of byte offset `off`.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_starts.binary_search(&off) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    pub fn is_test(&self, off: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| a <= off && off <= b)
    }

    /// Name of the innermost named fn containing `off`.
    pub fn enclosing_fn(&self, off: usize) -> Option<&str> {
        self.fns
            .iter()
            .filter(|s| s.open <= off && off <= s.close)
            .min_by_key(|s| s.close - s.open)
            .map(|s| s.name.as_str())
    }

    /// Iterator over identifier words in `code`.
    pub fn words(&self) -> Words<'_> {
        Words::new(&self.code)
    }

    pub fn word(&self, a: usize, b: usize) -> &str {
        std::str::from_utf8(&self.code[a..b]).unwrap_or("")
    }

    /// First non-whitespace offset at or after `i`.
    pub fn next_nonws(&self, i: usize) -> usize {
        let mut j = i;
        while j < self.code.len() && (self.code[j] as char).is_whitespace() {
            j += 1;
        }
        j
    }

    /// Last non-whitespace offset at or before `i`, if any.
    pub fn prev_nonws(&self, i: usize) -> Option<usize> {
        let mut j = i as i64;
        while j >= 0 && (self.code[j as usize] as char).is_whitespace() {
            j -= 1;
        }
        (j >= 0).then_some(j as usize)
    }
}

/// Iterator yielding `(start, end)` of identifier-shaped words.
pub struct Words<'a> {
    code: &'a [u8],
    i: usize,
}

impl<'a> Words<'a> {
    pub fn new(code: &'a [u8]) -> Words<'a> {
        Words { code, i: 0 }
    }
}

impl<'a> Iterator for Words<'a> {
    type Item = (usize, usize);

    fn next(&mut self) -> Option<(usize, usize)> {
        let code = self.code;
        let n = code.len();
        let mut i = self.i;
        while i < n {
            if (code[i].is_ascii_alphabetic() || code[i] == b'_')
                && (i == 0 || !is_ident(code[i - 1]))
            {
                let mut j = i + 1;
                while j < n && is_ident(code[j]) {
                    j += 1;
                }
                self.i = j;
                return Some((i, j));
            }
            i += 1;
        }
        self.i = i;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let src = br#"let a = "Instant::now()"; // Instant::now()
let b = 'x'; /* HashMap */ let c = 1;"#;
        let code = strip_code(src);
        let s = String::from_utf8(code).unwrap();
        assert!(!s.contains("Instant"));
        assert!(!s.contains("HashMap"));
        assert!(s.contains("let b ="));
        assert_eq!(s.len(), src.len());
    }

    #[test]
    fn lifetimes_survive_stripping() {
        let src = b"fn f<'a>(b: &'a [u8]) -> &'a [u8] { b }";
        let code = strip_code(src);
        assert_eq!(&code[..], &src[..]);
    }

    #[test]
    fn test_regions_cover_mod_tests() {
        let src =
            b"fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { bad(); }\n}\nfn live2() {}";
        let f = SourceFile::new("x.rs".into(), String::from_utf8(src.to_vec()).unwrap());
        let bad = f.raw.find("bad").unwrap();
        assert!(f.is_test(bad));
        assert!(!f.is_test(f.raw.find("live2").unwrap()));
    }

    #[test]
    fn enclosing_fn_innermost() {
        let src = "fn outer() { fn inner() { mark(); } }";
        let f = SourceFile::new("x.rs".into(), src.to_string());
        let mark = src.find("mark").unwrap();
        assert_eq!(f.enclosing_fn(mark), Some("inner"));
    }
}
