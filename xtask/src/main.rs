//! `cargo xtask` — repo tooling. One subcommand so far:
//!
//! ```text
//! cargo xtask analyze [--root DIR] [--allow FILE] [--pass NAME]... [-q]
//! ```
//!
//! Runs the static-analysis suite (determinism, regmap, panic passes)
//! over `<root>/rust/src`, matched against `<root>/analysis/allow.toml`
//! (override with `--allow`). Exits 1 on any unsuppressed finding,
//! 2 on usage/config errors.

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::{analyze, PassSet};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("analyze") => run_analyze(&args[1..]),
        Some(other) => {
            eprintln!("xtask: unknown subcommand `{other}`");
            eprintln!(
                "usage: cargo xtask analyze [--root DIR] [--allow FILE] [--pass NAME]... [-q]"
            );
            ExitCode::from(2)
        }
        None => {
            eprintln!(
                "usage: cargo xtask analyze [--root DIR] [--allow FILE] [--pass NAME]... [-q]"
            );
            ExitCode::from(2)
        }
    }
}

fn run_analyze(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut allow_path: Option<PathBuf> = None;
    let mut passes: Option<PassSet> = None;
    let mut quiet = false;

    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage_err("--root needs a value"),
            },
            "--allow" => match it.next() {
                Some(v) => allow_path = Some(PathBuf::from(v)),
                None => return usage_err("--allow needs a value"),
            },
            "--pass" => match it.next() {
                Some(v) => {
                    let set = passes.get_or_insert_with(PassSet::none);
                    if let Err(e) = set.enable(v) {
                        return usage_err(&e);
                    }
                }
                None => return usage_err("--pass needs a value"),
            },
            "-q" | "--quiet" => quiet = true,
            other => return usage_err(&format!("unknown flag `{other}`")),
        }
    }

    // Default root: the workspace root (xtask runs from anywhere via
    // the cargo alias; CARGO_MANIFEST_DIR is xtask/).
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."))
    });
    let allow_path = allow_path.unwrap_or_else(|| root.join("analysis").join("allow.toml"));

    let allow = match xtask::allow::load(&allow_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("xtask analyze: {e}");
            return ExitCode::from(2);
        }
    };

    let report = match analyze(&root, &allow, passes.unwrap_or_default()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("xtask analyze: {e}");
            return ExitCode::from(2);
        }
    };

    for stale in &report.unused_allows {
        eprintln!("warning: unused allow entry ({stale}) — prune it");
    }
    if report.findings.is_empty() {
        if !quiet {
            println!(
                "xtask analyze: clean ({} finding(s) suppressed by {} allow entr{})",
                report.suppressed,
                allow.len(),
                if allow.len() == 1 { "y" } else { "ies" },
            );
        }
        ExitCode::SUCCESS
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        println!(
            "xtask analyze: {} finding(s) ({} suppressed by the allowlist)",
            report.findings.len(),
            report.suppressed,
        );
        ExitCode::FAILURE
    }
}

fn usage_err(msg: &str) -> ExitCode {
    eprintln!("xtask analyze: {msg}");
    ExitCode::from(2)
}
