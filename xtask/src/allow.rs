//! `analysis/allow.toml` — the audited list of sanctioned findings.
//!
//! Hand-rolled parser for the tiny TOML subset the allowlist uses
//! (`[[allow]]` tables with string values only); pulling in a real
//! TOML crate would break the zero-dependency offline build. Every
//! entry MUST carry a `reason`: the allowlist is documentation of
//! *why* each wall seam / unresolved access is legitimate, not an
//! escape hatch.
//!
//! ```toml
//! [[allow]]
//! pass = "determinism"        # determinism | regmap | panic
//! path = "link/channel.rs"    # file, relative to rust/src
//! rule = "wall-clock"         # optional: restrict to one rule
//! func = "wait_any"           # optional: restrict to one fn
//! reason = "bounded wait deadline; never feeds simulated state"
//! ```

use std::path::Path;

use crate::Finding;

#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub pass: String,
    pub path: String,
    pub rule: Option<String>,
    pub func: Option<String>,
    pub reason: String,
    /// Line of the `[[allow]]` header (for unused-entry reports).
    pub line: usize,
}

impl AllowEntry {
    pub fn matches(&self, f: &Finding) -> bool {
        self.pass == f.pass
            && self.path == f.path
            && self.rule.as_deref().map_or(true, |r| r == f.rule)
            && self
                .func
                .as_deref()
                .map_or(true, |n| Some(n) == f.func.as_deref())
    }

    pub fn describe(&self) -> String {
        format!(
            "allow.toml:{}: pass={} path={}{}{}",
            self.line,
            self.pass,
            self.path,
            self.rule.as_deref().map(|r| format!(" rule={r}")).unwrap_or_default(),
            self.func.as_deref().map(|f| format!(" func={f}")).unwrap_or_default(),
        )
    }
}

const PASSES: [&str; 3] = ["determinism", "regmap", "panic"];

/// Parse the allowlist; errors carry line numbers.
pub fn parse(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out: Vec<AllowEntry> = Vec::new();
    let mut cur: Option<AllowEntry> = None;
    for (idx, raw_line) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = raw_line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            if let Some(e) = cur.take() {
                validate(&e)?;
                out.push(e);
            }
            cur = Some(AllowEntry {
                pass: String::new(),
                path: String::new(),
                rule: None,
                func: None,
                reason: String::new(),
                line: lineno,
            });
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("allow.toml:{lineno}: expected `key = \"value\"`"));
        };
        let Some(e) = cur.as_mut() else {
            return Err(format!(
                "allow.toml:{lineno}: key outside an [[allow]] table"
            ));
        };
        let key = key.trim();
        let value = value.trim();
        let unquoted = value
            .strip_prefix('"')
            .and_then(|v| v.strip_suffix('"'))
            .ok_or_else(|| {
                format!("allow.toml:{lineno}: value for `{key}` must be a quoted string")
            })?;
        if unquoted.contains('\\') || unquoted.contains('"') {
            return Err(format!(
                "allow.toml:{lineno}: escapes are not supported in values"
            ));
        }
        match key {
            "pass" => e.pass = unquoted.to_string(),
            "path" => e.path = unquoted.to_string(),
            "rule" => e.rule = Some(unquoted.to_string()),
            "func" => e.func = Some(unquoted.to_string()),
            "reason" => e.reason = unquoted.to_string(),
            other => {
                return Err(format!("allow.toml:{lineno}: unknown key `{other}`"));
            }
        }
    }
    if let Some(e) = cur.take() {
        validate(&e)?;
        out.push(e);
    }
    Ok(out)
}

fn validate(e: &AllowEntry) -> Result<(), String> {
    if !PASSES.contains(&e.pass.as_str()) {
        return Err(format!(
            "allow.toml:{}: `pass` must be one of {PASSES:?}, got `{}`",
            e.line, e.pass
        ));
    }
    if e.path.is_empty() {
        return Err(format!("allow.toml:{}: missing `path`", e.line));
    }
    if e.reason.trim().is_empty() {
        return Err(format!(
            "allow.toml:{}: every allow entry must carry a `reason` justifying it",
            e.line
        ));
    }
    Ok(())
}

/// Load and parse `path`; a missing file is an empty allowlist.
pub fn load(path: &Path) -> Result<Vec<AllowEntry>, String> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_entry() {
        let t = "# header\n[[allow]]\npass = \"determinism\"\npath = \"a.rs\"\nreason = \"r\"\n";
        let v = parse(t).unwrap();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].pass, "determinism");
        assert!(v[0].rule.is_none());
    }

    #[test]
    fn rejects_missing_reason() {
        let t = "[[allow]]\npass = \"panic\"\npath = \"a.rs\"\n";
        assert!(parse(t).unwrap_err().contains("reason"));
    }

    #[test]
    fn rejects_unknown_pass() {
        let t = "[[allow]]\npass = \"nope\"\npath = \"a.rs\"\nreason = \"r\"\n";
        assert!(parse(t).is_err());
    }
}
