"""AOT lowering: jax (L2+L1) → HLO *text* → artifacts/.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (consumed by ``rust/src/runtime/pjrt.rs``,
which documents the same contract from the other side).

Run once via ``make artifacts``; the rust binary is self-contained
afterwards — but note the artifacts are only read by builds with the
``pjrt`` cargo feature (``cargo build --features pjrt``). The default
build golden-checks against the pure-Rust native backend and needs
neither this script nor its outputs. A manifest file records every
artifact's entry signature so the rust runtime can sanity-check shapes
before compiling.
"""

from __future__ import annotations

import argparse
import hashlib
import os

import jax

# The verify/checksum graphs use int64 accumulators (overflow-safe
# multiset witnesses); without x64 jax silently downcasts them to
# int32, changing both semantics and the artifact's output dtype.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, function, example-arg builder)
_DTYPES = {
    "i32": jnp.int32,
    "f32": jnp.float32,
    "u32": jnp.uint32,
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_set(batches=(1, 8), n=1024):
    """Yield (filename, fn, specs, signature) for every artifact."""
    for b in batches:
        for dt_name, dt in _DTYPES.items():
            spec = jax.ShapeDtypeStruct((b, n), dt)
            yield (
                f"sort_{b}x{n}_{dt_name}.hlo.txt",
                model.sort_offload,
                (spec,),
                f"sort (x: {dt_name}[{b},{n}]) -> ({dt_name}[{b},{n}])",
            )
        spec_i32 = jax.ShapeDtypeStruct((b, n), jnp.int32)
        yield (
            f"sort_desc_{b}x{n}_i32.hlo.txt",
            model.sort_offload_desc,
            (spec_i32,),
            f"sort_desc (x: i32[{b},{n}]) -> (i32[{b},{n}])",
        )
        yield (
            f"verify_{b}x{n}_i32.hlo.txt",
            model.sort_and_verify,
            (spec_i32,),
            f"verify (x: i32[{b},{n}]) -> (i32[{b},{n}], pred[{b}])",
        )
        yield (
            f"checksum_{b}x{n}_i32.hlo.txt",
            model.record_checksum,
            (spec_i32,),
            f"checksum (x: i32[{b},{n}]) -> (i64[{b}])",
        )


def build(out_dir: str, batches=(1, 8), n=1024) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    written = []
    for fname, fn, specs, sig in artifact_set(batches, n):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest_lines.append(f"{fname}\t{sig}\t{digest}")
        written.append(path)
        print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--n", type=int, default=1024, help="record length")
    ap.add_argument(
        "--batches", type=int, nargs="+", default=[1, 8], help="batch sizes"
    )
    args = ap.parse_args()
    files = build(args.out, tuple(args.batches), args.n)
    print(f"AOT complete: {len(files)} artifacts in {args.out}")


if __name__ == "__main__":
    main()
