"""Pure-jnp oracle for the bitonic sorting-network kernel.

The RTL sorter, the Pallas kernel, and the AOT artifact all have to
agree with this reference; pytest enforces kernel == ref and the rust
integration tests enforce RTL == artifact (which was lowered from the
kernel), closing the loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sort(x: jax.Array, descending: bool = False) -> jax.Array:
    """Reference sort along the last axis."""
    y = jnp.sort(x, axis=-1)
    if descending:
        y = jnp.flip(y, axis=-1)
    return y
