"""L1 — Pallas bitonic sorting-network kernel.

This is the functional twin of the RTL streaming sorting network in
``rust/src/hdl/sorter.rs`` (itself a cycle-accurate model of the Spiral
streaming sorting network IP used by the paper). The hardware sorts
1024 32-bit signed integers in 1256 cycles through a pipeline of
compare-exchange stages; here the same bitonic network is expressed as
a Pallas kernel: each hardware stage becomes a full-width vector
min/max plus a static lane permutation over a VMEM-resident tile.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the sort axis (N lanes)
stays resident in VMEM across all log2(N)*(log2(N)+1)/2 stages — exactly
like the streaming network keeps the record set in BRAM between stages —
and BlockSpec tiles the *batch* axis so each grid step is one VMEM
round trip. This is a VPU (vector) workload; there is no MXU use.

The kernel must be lowered with ``interpret=True`` (CPU PJRT cannot run
Mosaic custom-calls); ``aot.py`` documents the HLO-text interchange this
feeds into, and ``rust/src/runtime/pjrt.rs`` is the consumer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def network_stages(n: int) -> list[tuple[int, int]]:
    """The (k, j) compare-exchange stage list of the bitonic network.

    ``k`` is the size of the monotonic runs being merged (direction
    block), ``j`` the partner distance. For n=1024 this yields the 55
    stages that the RTL pipeline implements.
    """
    if not _is_pow2(n):
        raise ValueError(f"bitonic network needs a power-of-two length, got {n}")
    stages = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            stages.append((k, j))
            j //= 2
        k *= 2
    return stages


def stage_apply(x: jax.Array, k: int, j: int, descending: bool = False) -> jax.Array:
    """Apply one compare-exchange stage across the last axis of ``x``.

    Mirrors one pipeline stage of the hardware network: every lane i is
    compared with lane i^j; the element order within each k-block
    alternates so that after the final stage the whole axis is sorted.
    """
    n = x.shape[-1]
    idx = jnp.arange(n, dtype=jnp.int32)
    partner = idx ^ j
    px = jnp.take(x, partner, axis=-1)
    # Ascending block if (i & k) == 0 (flipped globally for descending).
    up = (idx & k) == 0
    if descending:
        up = ~up
    # Lane keeps the min if it is the lower index of the pair in an
    # ascending block, or the higher index in a descending block.
    is_lower = (idx & j) == 0
    keep_min = jnp.where(is_lower, up, ~up)
    mn = jnp.minimum(x, px)
    mx = jnp.maximum(x, px)
    return jnp.where(keep_min, mn, mx)


def bitonic_sort_array(x: jax.Array, descending: bool = False) -> jax.Array:
    """Pure-jnp bitonic network over the last axis (used inside the
    kernel body and directly testable against ref.py)."""
    for k, j in network_stages(x.shape[-1]):
        x = stage_apply(x, k, j, descending)
    return x


def _sort_kernel(x_ref, o_ref, *, descending: bool):
    """Pallas kernel body: one VMEM tile of shape (block_b, n)."""
    o_ref[...] = bitonic_sort_array(x_ref[...], descending)


def sort(
    x: jax.Array,
    descending: bool = False,
    block_b: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Sort ``x`` of shape (batch, n) along the last axis with the
    bitonic-network Pallas kernel.

    ``block_b`` tiles the batch axis into VMEM-sized chunks; the sort
    axis is never split (the network needs all n lanes resident, like
    the hardware keeps the full record set in BRAM).
    """
    if x.ndim != 2:
        raise ValueError(f"expected (batch, n), got shape {x.shape}")
    b, n = x.shape
    if not _is_pow2(n):
        raise ValueError(f"sort axis must be a power of two, got {n}")
    if block_b is None:
        # One tile per VMEM round trip; cap the tile at ~512 KiB of
        # int32 so (tile + partner + min/max temps) fits 16 MiB VMEM.
        block_b = max(1, min(b, (512 * 1024) // (4 * n)))
    while b % block_b != 0:
        block_b -= 1
    grid = (b // block_b,)
    kernel = functools.partial(_sort_kernel, descending=descending)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
