"""L2 — JAX model of the sorting-offload accelerator datapath.

This is the compute graph the FPGA platform implements in hardware:
a batch of fixed-length records streams through the sorting network.
The rust runtime loads the AOT-lowered HLO of these functions and uses
them as (a) the golden model for checking cycle-accurate RTL results
after every offload and (b) the datapath of the functional fast mode
(``--mode func``), where the DMA stream is answered directly from the
compiled XLA executable instead of the RTL pipeline.

Python here is build-time only; nothing in this package is imported on
the co-simulation request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import bitonic


def sort_offload(x: jax.Array) -> tuple[jax.Array]:
    """The accelerator datapath: sort each 1024-element record.

    Input/output layout matches the DMA framing: shape (batch, n),
    elements in host memory order (little-endian int32 words on the
    128-bit stream = 4 consecutive lanes per beat).
    """
    return (bitonic.sort(x),)


def sort_offload_desc(x: jax.Array) -> tuple[jax.Array]:
    """Descending variant (the hardware sorter's ``order`` pin)."""
    return (bitonic.sort(x, descending=True),)


def sort_and_verify(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Datapath plus the host-side acceptance predicate: sorted output
    and a per-record flag that the output is a sorted permutation of
    the input (sum + min/max preserved and monotone non-decreasing).

    The rust coordinator runs this after each offload in ``--check
    golden`` mode so acceptance itself is an XLA computation, not
    host code.
    """
    y = bitonic.sort(x)
    monotone = jnp.all(y[:, 1:] >= y[:, :-1], axis=-1)
    # Multiset-preservation witnesses (cheap, not a full histogram):
    # sums in int64 to avoid overflow, plus extrema.
    sum_ok = jnp.sum(x.astype(jnp.int64), axis=-1) == jnp.sum(
        y.astype(jnp.int64), axis=-1
    )
    ext_ok = (jnp.min(x, axis=-1) == y[:, 0]) & (jnp.max(x, axis=-1) == y[:, -1])
    return y, monotone & sum_ok & ext_ok


def record_checksum(x: jax.Array) -> tuple[jax.Array]:
    """Order-invariant checksum of each record (int64 sum + xor mix),
    used by the coordinator to pair DMA input/output buffers without
    retaining the full input."""
    s = jnp.sum(x.astype(jnp.int64), axis=-1)
    # xor-fold in int32 domain, then widen.
    xr = jax.lax.reduce(
        x.astype(jnp.int32),
        jnp.int32(0),
        lambda a, b: jax.lax.bitwise_xor(a, b),
        dimensions=(1,),
    )
    # Keep the xor fold in the high 32 bits so a value edit cannot
    # cancel against the +/- delta it causes in the low (sum) bits.
    return ((xr.astype(jnp.int64) << 32) ^ s,)
