"""L1 correctness: Pallas bitonic kernel vs pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, and value distributions; explicit
tests pin the hardware configuration (1024 lanes, int32) and edge cases
(duplicates, extremes, already/reverse sorted).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitonic, ref

jax.config.update("jax_enable_x64", True)

DTYPES = {
    "i32": (jnp.int32, -(2**31), 2**31 - 1),
    "u32": (jnp.uint32, 0, 2**32 - 1),
    "f32": (jnp.float32, -1e30, 1e30),
}


def _rand(shape, dt_name, seed):
    dt, lo, hi = DTYPES[dt_name]
    rng = np.random.default_rng(seed)
    if dt_name == "f32":
        x = rng.uniform(lo, hi, size=shape).astype(np.float32)
    else:
        x = rng.integers(lo, hi, size=shape, dtype=np.int64).astype(
            np.int32 if dt_name == "i32" else np.uint32
        )
    return jnp.asarray(x, dtype=dt)


# ---------------------------------------------------------------- network


def test_network_stage_count_1024():
    # log2(1024)=10 → 10*11/2 = 55 compare-exchange stages, matching
    # the RTL pipeline depth accounting in rust/src/hdl/sorter.rs.
    assert len(bitonic.network_stages(1024)) == 55


@pytest.mark.parametrize("n", [1, 2, 4, 8, 64, 256, 1024])
def test_network_stage_count(n):
    import math

    lg = int(math.log2(n)) if n > 1 else 0
    assert len(bitonic.network_stages(n)) == lg * (lg + 1) // 2


def test_network_rejects_non_pow2():
    with pytest.raises(ValueError):
        bitonic.network_stages(1000)
    with pytest.raises(ValueError):
        bitonic.sort(jnp.zeros((1, 1000), jnp.int32))


def test_sort_rejects_bad_rank():
    with pytest.raises(ValueError):
        bitonic.sort(jnp.zeros((1024,), jnp.int32))


# ------------------------------------------------------- pinned hardware cfg


@pytest.mark.parametrize("dt_name", list(DTYPES))
def test_kernel_matches_ref_1024(dt_name):
    """The hardware configuration: 1024 lanes, batch 4."""
    x = _rand((4, 1024), dt_name, seed=7)
    got = bitonic.sort(x)
    want = ref.sort(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_descending_1024():
    x = _rand((2, 1024), "i32", seed=11)
    got = bitonic.sort(x, descending=True)
    want = ref.sort(x, descending=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_extremes_and_duplicates():
    row = np.zeros(1024, np.int32)
    row[:10] = np.int32(-(2**31))
    row[10:20] = np.int32(2**31 - 1)
    row[20:500] = 42
    x = jnp.asarray(np.stack([row, row[::-1].copy()]))
    got = np.asarray(bitonic.sort(x))
    want = np.asarray(ref.sort(x))
    np.testing.assert_array_equal(got, want)


def test_kernel_already_sorted_and_reversed():
    a = jnp.arange(1024, dtype=jnp.int32)[None, :]
    r = jnp.flip(a, axis=-1)
    np.testing.assert_array_equal(np.asarray(bitonic.sort(a)), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(bitonic.sort(r)), np.asarray(a))


def test_kernel_all_equal():
    x = jnp.full((3, 256), 77, jnp.int32)
    np.testing.assert_array_equal(np.asarray(bitonic.sort(x)), np.asarray(x))


def test_float_negative_zero_and_inf():
    row = np.array(
        [0.0, -0.0, np.inf, -np.inf, 1.5, -1.5, 3e38, -3e38] * 4, np.float32
    )
    x = jnp.asarray(row)[None, :]
    got = np.asarray(bitonic.sort(x))
    want = np.asarray(ref.sort(x))
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------- hypothesis sweep


@settings(max_examples=40, deadline=None)
@given(
    lg_n=st.integers(min_value=0, max_value=9),
    batch=st.integers(min_value=1, max_value=6),
    dt_name=st.sampled_from(list(DTYPES)),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    descending=st.booleans(),
)
def test_kernel_matches_ref_sweep(lg_n, batch, dt_name, seed, descending):
    n = 1 << lg_n
    x = _rand((batch, n), dt_name, seed)
    got = bitonic.sort(x, descending=descending)
    want = ref.sort(x, descending=descending)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    values=st.lists(
        st.integers(min_value=-(2**31), max_value=2**31 - 1),
        min_size=64,
        max_size=64,
    ),
)
def test_kernel_is_permutation(values):
    """Output is a sorted permutation of the input (multiset equal)."""
    x = jnp.asarray(np.array(values, np.int32))[None, :]
    got = np.asarray(bitonic.sort(x))[0]
    assert np.all(got[1:] >= got[:-1])
    assert sorted(values) == got.tolist()


@settings(max_examples=15, deadline=None)
@given(
    block_b=st.integers(min_value=1, max_value=8),
    batch=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_kernel_block_tiling_invariant(block_b, batch, seed):
    """Result must not depend on the VMEM tile size (BlockSpec)."""
    x = _rand((batch, 128), "i32", seed)
    base = np.asarray(bitonic.sort(x, block_b=None))
    tiled = np.asarray(bitonic.sort(x, block_b=min(block_b, batch)))
    np.testing.assert_array_equal(base, tiled)


def test_stage_apply_is_involution_free_permutation():
    """Each stage only permutes values within i / i^j pairs."""
    x = _rand((1, 64), "i32", seed=3)
    for k, j in bitonic.network_stages(64):
        y = bitonic.stage_apply(x, k, j)
        assert sorted(np.asarray(x)[0].tolist()) == sorted(
            np.asarray(y)[0].tolist()
        )
        x = y
