"""L2 model tests: offload datapath, verification graph, checksum."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def _rand_i32(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(-(2**31), 2**31 - 1, size=shape, dtype=np.int64).astype(
            np.int32
        )
    )


def test_sort_offload_matches_ref():
    x = _rand_i32((4, 1024), 1)
    (y,) = model.sort_offload(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref.sort(x)))


def test_sort_offload_desc():
    x = _rand_i32((2, 1024), 2)
    (y,) = model.sort_offload_desc(x)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(ref.sort(x, descending=True))
    )


def test_sort_and_verify_accepts_good_input():
    x = _rand_i32((8, 1024), 3)
    y, ok = model.sort_and_verify(x)
    assert np.all(np.asarray(ok))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref.sort(x)))


def test_verify_overflow_safe():
    # Sums that overflow int32 must not produce false rejections.
    x = jnp.full((1, 1024), 2**30, jnp.int32)
    _, ok = model.sort_and_verify(x)
    assert np.all(np.asarray(ok))


def test_checksum_order_invariant():
    x = _rand_i32((4, 1024), 5)
    perm = np.asarray(x).copy()
    rng = np.random.default_rng(0)
    for row in perm:
        rng.shuffle(row)
    (a,) = model.record_checksum(x)
    (b,) = model.record_checksum(jnp.asarray(perm))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checksum_discriminates():
    x = _rand_i32((1, 1024), 6)
    y = np.asarray(x).copy()
    y[0, 0] ^= 1
    (a,) = model.record_checksum(x)
    (b,) = model.record_checksum(jnp.asarray(y))
    assert np.asarray(a)[0] != np.asarray(b)[0]


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_sort_and_verify_sweep(batch, seed):
    x = _rand_i32((batch, 256), seed)
    y, ok = model.sort_and_verify(x)
    assert np.all(np.asarray(ok))
    got = np.asarray(y)
    assert np.all(got[:, 1:] >= got[:, :-1])
