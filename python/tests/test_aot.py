"""AOT path tests: lowering produces loadable HLO text + manifest."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_contains_entry(tmp_path):
    spec = jax.ShapeDtypeStruct((1, 64), jnp.int32)
    lowered = jax.jit(model.sort_offload).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # No Mosaic custom-calls may leak into the CPU artifact.
    assert "tpu_custom_call" not in text


def test_build_writes_artifacts_and_manifest(tmp_path):
    files = aot.build(str(tmp_path), batches=(1,), n=64)
    names = {Path(f).name for f in files}
    assert "sort_1x64_i32.hlo.txt" in names
    assert "verify_1x64_i32.hlo.txt" in names
    assert "checksum_1x64_i32.hlo.txt" in names
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == len(files)
    for line in manifest:
        fname, sig, digest = line.split("\t")
        assert (tmp_path / fname).exists()
        assert len(digest) == 16


def test_artifact_structure_and_manifest_digests(tmp_path):
    """The HLO text must carry the expected entry signature, and the
    manifest digests must match the files on disk. (The text → parse →
    compile → execute path itself is exercised by the rust runtime
    tests in rust/src/runtime/mod.rs, which is the consumer.)"""
    import hashlib

    files = aot.build(str(tmp_path), batches=(2,), n=128)
    text = (tmp_path / "sort_2x128_i32.hlo.txt").read_text()
    # Entry signature: s32[2,128] in, (s32[2,128]) tuple out.
    assert "s32[2,128]" in text
    assert "ENTRY" in text
    # Checksum artifact outputs s64.
    csum = (tmp_path / "checksum_2x128_i32.hlo.txt").read_text()
    assert "s64[2]" in csum
    for line in (tmp_path / "manifest.txt").read_text().strip().splitlines():
        fname, _sig, digest = line.split("\t")
        on_disk = hashlib.sha256(
            (tmp_path / fname).read_text().encode()
        ).hexdigest()[:16]
        assert on_disk == digest, f"digest mismatch for {fname}"
    assert len(files) == 6


def test_lowered_numerics_via_jit(tmp_path):
    """The exact function that gets lowered must match the oracle when
    executed (guards against lowering a different callable)."""
    rng = np.random.default_rng(0)
    x = rng.integers(-(2**31), 2**31 - 1, size=(2, 128), dtype=np.int64).astype(
        np.int32
    )
    (out,) = jax.jit(model.sort_offload)(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.sort(x, axis=-1))
