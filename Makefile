# Convenience targets. The default Rust build needs NONE of these —
# `cargo build --release && cargo test -q` is self-contained (native
# golden backend). `make artifacts` is only for the `pjrt` backend.

.PHONY: build test artifacts pytest

build:
	cargo build --release

test:
	cargo test -q

# Lower the jax/Pallas model to HLO-text artifacts for the PJRT golden
# backend (rust builds with `--features pjrt` read these at run time).
artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts

pytest:
	python3 -m pytest python/tests -q
