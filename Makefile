# Convenience targets. The default Rust build needs NONE of these —
# `cargo build --release && cargo test -q` is self-contained (native
# golden backend). `make artifacts` is only for the `pjrt` backend.

.PHONY: build test analyze artifacts pytest

build:
	cargo build --release

test:
	cargo test -q

# Static analysis over the co-sim core (determinism, regmap, panic
# passes against analysis/allow.toml) — same gate as the CI `analyze`
# job. See README "Static analysis".
analyze:
	cargo xtask analyze

# Lower the jax/Pallas model to HLO-text artifacts for the PJRT golden
# backend (rust builds with `--features pjrt` read these at run time).
artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts

pytest:
	python3 -m pytest python/tests -q
